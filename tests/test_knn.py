"""All-pairs k-NN graph tests (DESIGN.md section 12.3).

The acceptance sweep: ``repro.core.knn`` selfcheck — exact neighbor
index equality against the dense brute-force oracle for every execution
mode (batched / overlap / scan / fused kernel), both metrics, ragged
corpora, and underfull neighbor lists — for **every registered
placement** at P in {4, 5, 7, 8, 12, 13} where the placement is defined
(the same grid as the sparse-join sweep in tests/test_sparse.py).  Runs
in fake-device subprocesses (dry-run isolation rule, see
tests/test_distributed.py).

Host-level pieces (the brute-force oracle, argument validation, the env
mode override, the scatter's non-additive merge monoid) are covered
in-process.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.knn import brute_force_knn
from repro.core.placement import registered_placements
from repro.kernels.ref import IDX_SENTINEL, NEG_INF

SRC = Path(__file__).resolve().parents[1] / "src"

P_SWEEP = (4, 5, 7, 8, 12, 13)

KNN_CASES = [
    (P, name)
    for P in P_SWEEP
    for name, cls in sorted(registered_placements().items())
    if cls.supports(P)
]


def run_sub(code: str, devices: int, env_extra: dict | None = None) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    env.update(env_extra or {})
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("P,name", KNN_CASES,
                         ids=[f"{n}-P{P}" for P, n in KNN_CASES])
def test_knn_graph_matches_oracle(P, name):
    """Every mode + fused kernel under the placement returns the exact
    neighbor index lists of the dense oracle; the ragged tail and the
    underfull sentinel padding are asserted inside the selfcheck."""
    out = run_sub(
        f"from repro.core.knn import selfcheck_main; "
        f"selfcheck_main({P}, placement={name!r})", P)
    assert "knn selfcheck OK" in out
    assert f"placement={name}(" in out
    assert "batched,overlap,scan,kernel" in out


def test_knn_env_mode_override():
    """REPRO_ALLPAIRS_MODE steers the k-NN engine's auto mode (the
    shared override surface, DESIGN.md section 4): a forced mode still
    matches the oracle, and a conflict with the fused kernel raises."""
    code = """
import numpy as np, jax
from repro.core.knn import brute_force_knn, knn_graph
rng = np.random.default_rng(3)
corpus = rng.normal(size=(40, 8)).astype(np.float32)
mesh = jax.make_mesh((4,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
res = knn_graph(corpus, mesh, topk=5)        # auto -> forced scan
want = brute_force_knn(corpus, 5)
assert (res.indices == want.indices).all()
try:
    knn_graph(corpus, mesh, topk=5, use_kernel=True)
except ValueError as e:
    assert "conflicts with a fused batch_fn" in str(e), e
else:
    raise AssertionError("kernel + forced non-batched mode must raise")
print("KNN-ENV-OK")
"""
    out = run_sub(code, 4, env_extra={"REPRO_ALLPAIRS_MODE": "scan"})
    assert "KNN-ENV-OK" in out


def test_brute_force_knn_properties():
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(30, 6)).astype(np.float32)
    for metric in ("dot", "l2"):
        res = brute_force_knn(corpus, 7, metric)
        assert res.indices.shape == (30, 7)
        for r in range(30):
            row = res.indices[r]
            assert r not in row                       # self excluded
            assert len(set(row.tolist())) == 7        # distinct neighbors
            # scores descend under the (-score, index) order
            assert (np.diff(res.scores[r]) <= 1e-6).all()


def test_brute_force_knn_underfull_pads_sentinels():
    rng = np.random.default_rng(1)
    corpus = rng.normal(size=(4, 3)).astype(np.float32)
    res = brute_force_knn(corpus, 6)
    assert (res.indices[:, 3:] == IDX_SENTINEL).all()
    assert (res.scores[:, 3:] == NEG_INF).all()
    assert (res.indices[:, :3] != IDX_SENTINEL).all()


def test_knn_graph_single_device():
    """P = 1 degenerates to the self tile only — the whole graph from
    one block, still oracle-exact (in-process, one real CPU device)."""
    import jax

    from repro.core.knn import knn_graph

    rng = np.random.default_rng(2)
    corpus = rng.normal(size=(17, 5)).astype(np.float32)
    mesh = jax.make_mesh((1,), ("q",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    for metric in ("dot", "l2"):
        want = brute_force_knn(corpus, 4, metric)
        for mode in ("batched", "scan"):
            got = knn_graph(corpus, mesh, topk=4, metric=metric, mode=mode)
            np.testing.assert_array_equal(got.indices, want.indices)
            np.testing.assert_allclose(got.scores, want.scores,
                                       rtol=1e-5, atol=1e-5)


def test_knn_argument_validation():
    import jax.numpy as jnp

    from repro.core.knn import quorum_allpairs_knn

    with pytest.raises(ValueError, match="metric"):
        quorum_allpairs_knn(jnp.zeros((4, 3)), topk=2, axis_name="q",
                            axis_size=2, metric="cosine")
    with pytest.raises(ValueError, match="topk"):
        quorum_allpairs_knn(jnp.zeros((4, 3)), topk=0, axis_name="q",
                            axis_size=2)
    with pytest.raises(ValueError, match="batch_fn"):
        quorum_allpairs_knn(jnp.zeros((4, 3)), topk=2, axis_name="q",
                            axis_size=2, mode="scan", batch_fn=lambda *a: a)

"""Tests for ckpt/checkpoint.py (previously untested).

In-process: save/restore roundtrips, the atomic-commit manifest rule,
retention GC, and the async manager's error surfacing.  In fake-device
subprocesses (dry-run isolation rule): a sharded roundtrip across
placements, and a P-rescale restore driven by ``elastic.rescale`` — the
checkpoint stores the *global* arrays, so a resize is a restore under
the new mesh plus the plan's residency fetches.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.ckpt import (CheckpointManager, load_checkpoint, save_checkpoint)
from repro.ckpt.checkpoint import latest_step

SRC = Path(__file__).resolve().parents[1] / "src"


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": rng.normal(size=(12, 4)).astype(np.float32),
                   "b": rng.normal(size=(4,)).astype(np.float32)},
        "step": np.int32(seed),
        "scales": [rng.uniform(size=(3,)).astype(np.float32),
                   rng.uniform(size=(5,)).astype(np.float32)],
    }


def _assert_tree_equal(a, b):
    import jax
    fa, _ = jax.tree.flatten(a)
    fb, _ = jax.tree.flatten(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    tree = _tree(3)
    path = save_checkpoint(tmp_path, 7, tree)
    assert path == tmp_path / "step_7"
    assert (path / "MANIFEST.json").exists()
    like = _tree(0)                       # same structure, different values
    restored, step = load_checkpoint(tmp_path, like)
    assert step == 7
    _assert_tree_equal(restored, tree)


def test_latest_step_requires_manifest(tmp_path):
    assert latest_step(tmp_path) is None
    save_checkpoint(tmp_path, 1, _tree(1))
    save_checkpoint(tmp_path, 5, _tree(5))
    # a crash mid-write leaves no MANIFEST: the step must be ignored
    torn = tmp_path / "step_9"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"torn write")
    assert latest_step(tmp_path) == 5
    restored, step = load_checkpoint(tmp_path, _tree(0))
    assert step == 5
    _assert_tree_equal(restored, _tree(5))


def test_dtype_restored_from_target_structure(tmp_path):
    import jax.numpy as jnp
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    save_checkpoint(tmp_path, 0, {"w": jnp.asarray(tree["w"], jnp.bfloat16)})
    like = {"w": jnp.zeros((2, 3), jnp.bfloat16)}
    restored, _ = load_checkpoint(tmp_path, like)
    assert restored["w"].dtype == jnp.bfloat16


def test_manager_async_gc_and_resume(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
        mgr.wait()
    kept = sorted(d.name for d in tmp_path.iterdir())
    assert kept == ["step_3", "step_4"]
    restored, step = mgr.restore_latest(_tree(0))
    assert step == 4
    _assert_tree_equal(restored, _tree(4))


def test_manager_surfaces_async_errors(tmp_path, monkeypatch):
    mgr = CheckpointManager(tmp_path / "sub", keep=2)
    import repro.ckpt.checkpoint as ck

    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(ck, "save_checkpoint", boom)
    mgr.save_async(1, _tree(1))
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    # the error is consumed: the manager is reusable afterwards
    monkeypatch.undo()
    mgr.save_async(2, _tree(2))
    mgr.wait()
    assert latest_step(tmp_path / "sub") == 2


def run_sub(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_roundtrip_across_placements(tmp_path):
    """Save a corpus sharded under one placement's mesh, restore it under
    another placement (and its residency): the checkpoint stores global
    arrays, so a placement migration is a restore + the rescale plan's
    residency delta."""
    code = f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS
from repro.ckpt import save_checkpoint, load_checkpoint
from repro.core.placement import get_placement
from repro.launch.elastic import rescale

tmp = {str(tmp_path)!r}
P = 12
mesh = jax.make_mesh((P,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sh = NamedSharding(mesh, PS("q"))
rng = np.random.default_rng(0)
corpus = rng.normal(size=(P * 8, 16)).astype(np.float32)
tree = {{"corpus": jax.device_put(jnp.asarray(corpus), sh),
         "step": jnp.int32(11)}}
save_checkpoint(tmp, 11, tree)

# same-P placement migrations: affine (P = 12 is a plane P) fetches at
# most its residency delta; full replication must fetch the complement
plan_affine = rescale(P, P, "cyclic", "affine")
assert plan_affine.is_migration
plan = rescale(P, P, "cyclic", "full")
assert plan.is_migration and plan.total_fetch_blocks > 0
like = {{"corpus": jnp.zeros_like(tree["corpus"]), "step": jnp.int32(0)}}
restored, step = load_checkpoint(tmp, like,
                                 shardings={{"corpus": sh, "step": None}})
assert step == 11
np.testing.assert_array_equal(np.asarray(restored["corpus"]), corpus)
assert restored["corpus"].sharding == sh
# every device can materialize its new residency from the restored global
block = corpus.shape[0] // P
for dev, res in enumerate(plan.new_quorums):
    for b in res:
        np.testing.assert_array_equal(
            np.asarray(restored["corpus"][b * block:(b + 1) * block]),
            corpus[b * block:(b + 1) * block])
print("CKPT-PLACEMENT-OK")
"""
    assert "CKPT-PLACEMENT-OK" in run_sub(code, 12)


def test_rescale_restore(tmp_path):
    """P-rescale restore: a checkpoint written under P_old restores under
    a P_new mesh (re-chunked residency from elastic.rescale); values are
    bit-identical and the fetch plan covers every new residency set."""
    code = f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS
from repro.ckpt import save_checkpoint, load_checkpoint
from repro.launch.elastic import rescale

tmp = {str(tmp_path)!r}
P_old, P_new = 4, 6
N, d = 48, 8                      # divisible by both P values
devs = jax.devices()
mesh_old = jax.make_mesh((P_old,), ("q",), devices=devs[:P_old])
rng = np.random.default_rng(1)
corpus = rng.normal(size=(N, d)).astype(np.float32)
x = jax.device_put(jnp.asarray(corpus), NamedSharding(mesh_old, PS("q")))
save_checkpoint(tmp, 3, {{"corpus": x}})

plan = rescale(P_old, P_new)
assert plan.P_new == P_new and plan.schedule.P == P_new
# a resize reuses nothing: every device fetches its whole new residency
assert plan.fetches == {{i: list(q) for i, q in enumerate(plan.new_quorums)}}

mesh_new = jax.make_mesh((P_new,), ("q",), devices=devs[:P_new])
sh_new = NamedSharding(mesh_new, PS("q"))
restored, step = load_checkpoint(tmp, {{"corpus": jnp.zeros((N, d))}},
                                 shardings={{"corpus": sh_new}})
assert step == 3
np.testing.assert_array_equal(np.asarray(restored["corpus"]), corpus)
assert restored["corpus"].sharding == sh_new
block = N // P_new
for dev, res in enumerate(plan.new_quorums):   # new residency materializes
    for b in res:
        np.testing.assert_array_equal(
            np.asarray(restored["corpus"][b * block:(b + 1) * block]),
            corpus[b * block:(b + 1) * block])
print("CKPT-RESCALE-OK")
"""
    assert "CKPT-RESCALE-OK" in run_sub(code, 6)

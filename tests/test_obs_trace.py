"""Tracer core invariants (obs/trace.py, DESIGN.md section 14.1):
span nesting/ordering, counters, Chrome-trace export, the REPRO_TRACE /
REPRO_METRICS activation matrix, and the disabled path's zero-cost
contract (the falsy NOOP singleton adds no net allocations).
"""

import json
import os
import subprocess
import sys
import tracemalloc
from pathlib import Path

import numpy as np
import pytest

from repro.obs import report as report_mod
from repro.obs import trace as trace_mod

SRC = Path(__file__).resolve().parents[1] / "src"


@pytest.fixture(autouse=True)
def _fresh_tracer_state(monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    monkeypatch.delenv("REPRO_METRICS", raising=False)
    trace_mod.reset()
    yield
    trace_mod.reset()


def test_nbytes_of():
    assert trace_mod.nbytes_of(np.zeros((3, 5), np.float32)) == 60
    assert trace_mod.nbytes_of(np.zeros((4,), np.int64)) == 32


def test_span_nesting_and_ordering():
    """Children close (and are appended) before their parents; depth and
    parent attributes record the nesting; child intervals are contained
    in the parent's."""
    tr = trace_mod.Tracer()
    with tr.span("outer", P=8):
        with tr.span("inner.a"):
            pass
        with tr.span("inner.b", round=1):
            pass
    names = [e["name"] for e in tr.events]
    assert names == ["inner.a", "inner.b", "outer"]
    by_name = {e["name"]: e for e in tr.events}
    assert by_name["outer"]["args"]["depth"] == 0
    assert by_name["outer"]["args"]["P"] == 8
    for child in ("inner.a", "inner.b"):
        ev = by_name[child]
        assert ev["args"]["depth"] == 1
        assert ev["args"]["parent"] == "outer"
        # containment: the child's interval sits inside the parent's
        parent = by_name["outer"]
        assert ev["ts"] >= parent["ts"]
        assert ev["ts"] + ev["dur"] <= parent["ts"] + parent["dur"] + 1e-6
    assert by_name["inner.b"]["args"]["round"] == 1
    # ts is monotone in append order for sequential siblings
    assert by_name["inner.a"]["ts"] <= by_name["inner.b"]["ts"]
    assert tr._stack == []


def test_span_exception_still_recorded():
    tr = trace_mod.Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    assert [e["name"] for e in tr.events] == ["boom"]
    assert tr._stack == []


def test_record_and_counters():
    tr = trace_mod.Tracer()
    tr.record("phase", 0.25, device=3, what="restore")
    tr.count("bytes", 100, device=0)
    tr.count("bytes", 50, device=1)
    tr.count("bytes", 7, device=0)
    tr.count("events")
    (ev,) = tr.events
    assert ev["name"] == "phase" and ev["pid"] == 3
    assert abs(ev["dur"] - 0.25e6) < 1e-3
    assert tr.counter_total("bytes") == 157
    assert tr.counters_by_device("bytes") == {0: 107, 1: 50}
    assert tr.counter_names() == ["bytes", "events"]


def test_chrome_trace_export_roundtrip(tmp_path):
    """export() writes Chrome-trace JSON the report module validates and
    summarizes; the repro section carries exact counter totals."""
    tr = trace_mod.Tracer(path=tmp_path / "t.json")
    tr.meta["P"] = 8
    with tr.span("sweep.gather"):
        pass
    tr.count("comm.bytes", 4096, device=2)
    out = tr.export()
    assert out == tmp_path / "t.json"
    obj = report_mod.load_trace(out)   # raises on an invalid trace
    assert report_mod.validate_chrome_trace(obj) == []
    phs = {e["ph"] for e in obj["traceEvents"]}
    assert phs == {"X", "C"}
    assert obj["repro"]["version"] == trace_mod.TRACE_FORMAT_VERSION
    assert obj["repro"]["counters"]["comm.bytes"]["2"] == 4096
    assert obj["repro"]["meta"] == {"P": 8}
    summary = report_mod.span_summary(obj)
    assert summary["sweep.gather"]["count"] == 1


def test_export_without_path_raises():
    with pytest.raises(ValueError, match="no export path"):
        trace_mod.Tracer().export()


def test_metrics_only_drops_spans():
    tr = trace_mod.Tracer(metrics_only=True)
    with tr.span("x"):
        tr.count("c", 2)
    tr.record("y", 0.1)
    assert tr.events == []
    assert tr.counter_total("c") == 2


def test_env_activation_matrix(monkeypatch):
    """Unset/0 -> falsy NOOP; 1 -> tracer at the default path; any other
    value -> tracer at that path; REPRO_METRICS=1 -> counters only;
    invalid REPRO_METRICS raises (the registry contract)."""
    assert trace_mod.get_tracer() is trace_mod.NOOP
    assert not trace_mod.get_tracer()

    monkeypatch.setenv("REPRO_TRACE", "0")
    trace_mod.reset()
    assert trace_mod.get_tracer() is trace_mod.NOOP

    monkeypatch.setenv("REPRO_TRACE", "1")
    trace_mod.reset()
    tr = trace_mod.get_tracer()
    assert tr and str(tr.path) == trace_mod.DEFAULT_TRACE_PATH
    assert trace_mod.get_tracer() is tr        # cached on the env values

    monkeypatch.setenv("REPRO_TRACE", "/tmp/custom_trace.json")
    tr2 = trace_mod.get_tracer()               # key change rebuilds
    assert tr2 is not tr and str(tr2.path) == "/tmp/custom_trace.json"

    monkeypatch.delenv("REPRO_TRACE")
    monkeypatch.setenv("REPRO_METRICS", "1")
    trace_mod.reset()
    tr3 = trace_mod.get_tracer()
    assert tr3 and tr3.metrics_only and tr3.path is None

    monkeypatch.setenv("REPRO_METRICS", "-1")
    trace_mod.reset()
    with pytest.raises(ValueError, match="REPRO_METRICS must be >= 0"):
        trace_mod.get_tracer()


def test_configure_overrides_env_and_reset_restores(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "1")
    trace_mod.reset()
    forced = trace_mod.configure(metrics_only=True)
    assert trace_mod.get_tracer() is forced
    trace_mod.reset()
    got = trace_mod.get_tracer()
    assert got is not forced and isinstance(got, trace_mod.Tracer)


def test_env_tracer_flushes_at_exit(tmp_path):
    """The REPRO_TRACE=<path> tracer exports at process exit (what the
    CI trace-smoke job relies on)."""
    out = tmp_path / "exit_trace.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC)
    env["REPRO_TRACE"] = str(out)
    code = ("from repro.obs import trace as t\n"
            "tr = t.get_tracer()\n"
            "assert tr\n"
            "tr.count('smoke', 3)\n"
            "with tr.span('s'):\n"
            "    pass\n")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr
    obj = report_mod.load_trace(out)
    assert obj["repro"]["counters"]["smoke"]["-1"] == 3


def test_disabled_path_zero_net_allocations():
    """The no-op overhead contract (ISSUE 7): with tracing off, the
    instrumented call-site pattern — get_tracer, falsy guard, singleton
    span — leaves zero net allocations behind per round."""
    def sweep_round():
        tr = trace_mod.get_tracer()
        if tr:  # pragma: no cover - tracing is off in this test
            with tr.span("sweep.pair_compute", mode="batched"):
                tr.count("sweep.pair_tiles", 15)
        return tr

    assert sweep_round() is trace_mod.NOOP     # the shared singleton
    # the interpreter makes a few one-time warm-up allocations once
    # tracemalloc starts watching; the claim is that the disabled path
    # reaches a steady state with zero net growth per 2000-round block
    tracemalloc.start()
    try:
        last = -1
        for _ in range(8):
            before, _ = tracemalloc.get_traced_memory()
            for _ in range(2000):
                sweep_round()
            after, _ = tracemalloc.get_traced_memory()
            last = after - before    # rebind, don't accumulate: the test
            if last == 0:            # itself must not allocate in-window
                break
        # slack of one small object (28 B): the measurement's own int
        # rebinding can land in-window under pytest.  A real per-round
        # allocation would grow the block by >= 2000 * 28 bytes.
        assert last <= 28, (
            f"disabled tracing allocates per round: last 2000-round "
            f"block grew {last} bytes")
    finally:
        tracemalloc.stop()

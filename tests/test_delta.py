"""Incremental delta-sweep (core/delta.py): the dirty-tile schedule,
the exactly-once ownership partition, per-emitter retract/fold rules,
the churn-chaos differential selfcheck, and the plan-stability contract
shared with failure recovery (DESIGN.md section 16)."""

import numpy as np
import pytest

from repro.core.allpairs import DenseReduceEmitter
from repro.core.delta import (DELTA_P, DeltaIndex, churn_selfcheck,
                              churn_workload, delta_rounds, delta_sweep,
                              dirty_tiles, owner_partition, scratch_fold)
from repro.core.faults import (DenseReduceWorkload, KnnGraphWorkload,
                               SparseJoinWorkload, WORKLOADS)
from repro.core.knn import KnnEmitter
from repro.core.placement import (get_placement, registered_placements,
                                  weighted_owner_table)
from repro.core.scheduler import reassign
from repro.core.sparse import ThresholdJoinEmitter
from repro.core.sweep import ENGINE_MODES, SweepEmitter, sweep_rounds


# ---------------------------------------------------------------------------
# dirty_tiles — the shared dirty-tile enumerator
# ---------------------------------------------------------------------------

def _brute_dirty(P, dirty):
    D = set(dirty)
    return {(x, y) for x in range(P) for y in range(x, P)
            if x in D or y in D}


@pytest.mark.parametrize("P,dirty", [
    (5, [0]), (7, [1, 4]), (8, [7]), (13, [0, 6, 12]), (4, [0, 1, 2, 3]),
])
def test_dirty_tiles_covers_exactly_dirty_endpoints(P, dirty):
    tiles = dirty_tiles(None, dirty, P=P)
    assert set(tiles) == _brute_dirty(P, dirty)
    assert tiles == sorted(tiles)                      # canonical order
    d = len(set(dirty))
    assert len(tiles) == d * P - d * (d - 1) // 2      # exact count
    assert len(tiles) <= d * P                         # the ISSUE bound


def test_dirty_tiles_deterministic_and_placement_P():
    plc = get_placement("cyclic", 8)
    a = dirty_tiles(plc, [2, 5])
    b = dirty_tiles(plc, [5, 2])          # order of the dirty set is moot
    c = dirty_tiles(None, {2, 5}, P=8)
    assert a == b == c == sorted(a)


def test_dirty_tiles_validates():
    with pytest.raises(ValueError, match="placement or an explicit P"):
        dirty_tiles(None, [0])
    with pytest.raises(ValueError, match="outside"):
        dirty_tiles(None, [5], P=5)
    with pytest.raises(ValueError, match="outside"):
        dirty_tiles(None, [-1], P=5)
    assert dirty_tiles(None, [], P=5) == []


def test_dirty_tiles_empty_dirty_set_everywhere():
    for P in (1, 2, 5):
        assert dirty_tiles(None, [], P=P) == []
        full = dirty_tiles(None, range(P), P=P)
        assert len(full) == P * (P + 1) // 2  # all-dirty == full sweep


# ---------------------------------------------------------------------------
# owner_partition — exactly-once over the holder quorums
# ---------------------------------------------------------------------------

def _supported_P(name):
    cls = registered_placements()[name]
    return next(P for P in (8, 7, 12, 5) if cls.supports(P))


@pytest.mark.parametrize("name", sorted(registered_placements()))
def test_owner_partition_exactly_once_and_coresident(name):
    P = _supported_P(name)
    plc = get_placement(name, P)
    owners = owner_partition(plc)
    assert set(owners) == {(x, y) for x in range(P) for y in range(x, P)}
    for (x, y), o in owners.items():
        res = plc.residency_sets[o]
        assert x in res and y in res, (name, (x, y), o)
        assert o == plc.owner_of(x, y)


def test_owner_partition_weighted_matches_table():
    P = 8
    plc = get_placement("cyclic", P)
    weights = [4.0 if i == 0 else 1.0 for i in range(P)]
    owners = owner_partition(plc, weights=weights)
    table = weighted_owner_table(plc, weights)
    for (x, y), o in owners.items():
        assert o == int(table[x, y])


def test_owner_partition_subset_of_tiles():
    plc = get_placement("cyclic", 5)
    tiles = dirty_tiles(plc, [3])
    owners = owner_partition(plc, tiles)
    assert set(owners) == set(tiles)


# ---------------------------------------------------------------------------
# delta_rounds — tiles land in the mode's synchronization rounds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [4, 5, 8, 13])
@pytest.mark.parametrize("mode", ENGINE_MODES)
def test_delta_rounds_partition_tiles(P, mode):
    plc = get_placement("cyclic", P)
    tiles = dirty_tiles(plc, [0, P - 1])
    rounds = delta_rounds(plc, tiles, mode)
    flat = [t for grp in rounds for t in grp]
    assert sorted(flat) == sorted(tiles)   # exactly once
    assert all(grp == sorted(grp) for grp in rounds)
    assert all(grp for grp in rounds)      # no empty rounds
    if mode == "batched":
        assert len(rounds) == 1
    if mode == "scan":
        assert rounds == [[t] for t in sorted(tiles)]


def test_delta_rounds_never_more_rounds_than_full_sweep():
    plc = get_placement("cyclic", 8)
    tiles = dirty_tiles(plc, [2])
    for mode in ("batched", "overlap"):
        assert (len(delta_rounds(plc, tiles, mode))
                <= len(sweep_rounds(plc.schedule(), mode)))


def test_delta_rounds_rejects_bad_mode():
    plc = get_placement("cyclic", 4)
    with pytest.raises(ValueError, match="mode"):
        delta_rounds(plc, [(0, 1)], "auto")


# ---------------------------------------------------------------------------
# delta_sweep — fresh partials equal a direct recompute
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wl_cls", WORKLOADS, ids=lambda c: c.name)
def test_delta_sweep_partials_match_direct(wl_cls):
    P = 7
    plc = get_placement("projective", P)
    wl = wl_cls(P, seed=1)
    fresh = delta_sweep(wl, plc, [3], mode="overlap")
    assert set(fresh) == set(dirty_tiles(plc, [3]))
    for (x, y), part in fresh.items():
        want = wl.pair_partial(x, y, wl.blocks[x], wl.blocks[y])
        if isinstance(part, dict):
            assert set(part) == set(want)
            for k in part:
                np.testing.assert_array_equal(part[k], want[k])
        else:
            np.testing.assert_array_equal(np.asarray(part), np.asarray(want))


# ---------------------------------------------------------------------------
# SweepEmitter delta hooks — base class refuses, emitters implement
# ---------------------------------------------------------------------------

def test_base_emitter_has_no_delta_rule():
    with pytest.raises(NotImplementedError, match="delta_retract"):
        SweepEmitter.delta_retract(0.0, 0.0)
    with pytest.raises(NotImplementedError, match="delta_fold"):
        SweepEmitter.delta_fold(0.0, 0.0)


def test_dense_emitter_subtract_then_add():
    total = DenseReduceEmitter.delta_retract(np.float64(10.0), 4.0)
    total = DenseReduceEmitter.delta_fold(total, 1.5)
    assert total == np.float64(7.5)
    assert isinstance(total, np.float64)


def test_join_emitter_hit_set_patch():
    standing = np.array([[0, 1], [2, 5], [3, 4]], np.int64)
    stale = np.array([[2, 5]], np.int64)
    out = ThresholdJoinEmitter.delta_retract(standing, stale)
    assert out.tolist() == [[0, 1], [3, 4]]
    ins = np.array([[2, 6], [0, 9]], np.int64)
    out = ThresholdJoinEmitter.delta_fold(out, ins)
    assert out.tolist() == [[0, 1], [0, 9], [2, 6], [3, 4]]  # (lo, hi) sorted
    # empty edges
    empty = np.zeros((0, 2), np.int64)
    assert ThresholdJoinEmitter.delta_retract(standing, empty).tolist() \
        == standing.tolist()
    assert ThresholdJoinEmitter.delta_retract(empty, stale).shape == (0, 2)


def test_knn_emitter_merge_is_rowwise_topk():
    s1 = np.array([[3.0, 1.0], [5.0, -np.inf]], np.float32)
    i1 = np.array([[7, 9], [2, np.iinfo(np.int64).max]], np.int64)
    s2 = np.array([[2.0, 3.0], [5.0, 6.0]], np.float32)
    i2 = np.array([[8, 4], [1, 0]], np.int64)
    ms, mi = KnnEmitter.delta_fold((s1, i1), (s2, i2))
    assert ms.shape == (2, 2)
    # row 0: scores 3,3,2,1 -> ties on 3 break by smaller index (4 < 7)
    assert ms[0].tolist() == [3.0, 3.0] and mi[0].tolist() == [4, 7]
    # row 1: 6@0, 5@1 (tie 5 breaks to index 1 < 2)
    assert ms[1].tolist() == [6.0, 5.0] and mi[1].tolist() == [0, 1]


def test_knn_emitter_retract_flags_citing_rows():
    best_i = np.array([[0, 5], [9, 3], [7, 8]], np.int64)
    starts = np.array([4], np.int64)
    stops = np.array([6], np.int64)   # dirty id range [4, 6)
    mask = KnnEmitter.delta_retract((None, best_i), (starts, stops))
    assert mask.tolist() == [True, False, False]


# ---------------------------------------------------------------------------
# DeltaIndex — per-workload bit-exact maintenance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wl_cls", WORKLOADS, ids=lambda c: c.name)
@pytest.mark.parametrize("mode", ENGINE_MODES)
def test_delta_index_bit_exact_under_updates(wl_cls, mode):
    P = 7
    plc = get_placement("projective", P)
    wl = churn_workload(wl_cls, P, seed=3)
    index = DeltaIndex(wl, plc, mode=mode)
    assert wl.equal(index.result, scratch_fold(wl))
    rng = np.random.RandomState(11)
    dim = wl.blocks[0].shape[1]
    # replace, shrink, append, and a two-block update
    updates = [
        (2, rng.randn(wl.blocks[2].shape[0], dim)),          # same-size
        (4, rng.randn(1, dim)),                              # shrink to 1 row
        (4, rng.randn(index.span_of(4), dim)),               # grow to capacity
    ]
    for b, data in updates:
        index.replace_block(b, data.astype(np.float32))
        out = index.apply()
        assert index.stats.last_tiles <= P
        assert wl.equal(out, scratch_fold(wl))
    index.replace_block(0, rng.randn(2, dim).astype(np.float32))
    index.replace_block(6, rng.randn(2, dim).astype(np.float32))
    out = index.apply()
    assert index.stats.last_tiles <= 2 * P
    assert wl.equal(out, scratch_fold(wl))
    assert index.stats.updates == 4


def test_delta_index_sweeps_fewer_tiles_than_full():
    P = 13
    plc = get_placement("projective", P)
    wl = churn_workload(DenseReduceWorkload, P, seed=0)
    index = DeltaIndex(wl, plc)
    full = index.stats.tiles_full
    assert full == P * (P + 1) // 2
    index.replace_block(5, np.zeros((1, wl.blocks[0].shape[1]), np.float32))
    index.apply()
    assert 0 < index.stats.last_tiles <= P < full


def test_delta_index_dense_running_total_tracks_refold():
    P = 8
    plc = get_placement("cyclic", P)
    wl = churn_workload(DenseReduceWorkload, P, seed=5)
    index = DeltaIndex(wl, plc)
    rng = np.random.RandomState(0)
    for b in (1, 6, 3):
        index.replace_block(
            b, rng.randn(2, wl.blocks[0].shape[1]).astype(np.float32))
        out = index.apply()
        # the fast-path running total is the same sum in a different
        # association order — close, while the published refold is exact
        np.testing.assert_allclose(
            float(index._running_total), float(out), rtol=1e-9)
        assert wl.equal(out, scratch_fold(wl))


def test_delta_index_knn_counts_refreshed_and_merged_rows():
    P = 8
    plc = get_placement("cyclic", P)
    wl = churn_workload(KnnGraphWorkload, P, seed=2)
    index = DeltaIndex(wl, plc)
    rng = np.random.RandomState(4)
    index.replace_block(
        3, rng.randn(2, wl.blocks[0].shape[1]).astype(np.float32))
    out = index.apply()
    assert wl.equal(out, scratch_fold(wl))
    assert index.stats.rows_refreshed > 0   # the dirty block's own rows
    assert index.stats.rows_merged > 0      # clean rows took the fast merge


def test_delta_index_sparse_counts_hit_patches():
    P = 8
    plc = get_placement("cyclic", P)
    wl = churn_workload(SparseJoinWorkload, P, seed=2)
    index = DeltaIndex(wl, plc)
    rng = np.random.RandomState(4)
    index.replace_block(
        0, rng.randn(2, wl.blocks[0].shape[1]).astype(np.float32))
    out = index.apply()
    assert wl.equal(out, scratch_fold(wl))
    assert index.stats.hits_retracted >= 0
    assert index.stats.hits_inserted >= 0


def test_delta_index_mark_dirty_listener_form():
    P = 5
    plc = get_placement("cyclic", P)
    wl = churn_workload(DenseReduceWorkload, P, seed=7)
    index = DeltaIndex(wl, plc)
    rng = np.random.RandomState(1)
    wl.blocks[2] = rng.randn(
        wl.blocks[2].shape[0], wl.blocks[2].shape[1]).astype(np.float32)
    index.mark_dirty(2)
    out = index.apply()
    assert wl.equal(out, scratch_fold(wl))
    with pytest.raises(ValueError, match="outside"):
        index.mark_dirty(P)


def test_delta_index_apply_without_dirty_is_a_noop():
    P = 5
    plc = get_placement("cyclic", P)
    wl = churn_workload(DenseReduceWorkload, P, seed=0)
    index = DeltaIndex(wl, plc)
    before = index.stats.updates
    out = index.apply()
    assert wl.equal(out, scratch_fold(wl))
    assert index.stats.updates == before


def test_delta_index_max_dirty_falls_back_to_full_rebuild():
    P = 5
    plc = get_placement("cyclic", P)
    wl = churn_workload(DenseReduceWorkload, P, seed=0)
    index = DeltaIndex(wl, plc, max_dirty_pct=0)   # any dirt -> full rebuild
    rng = np.random.RandomState(2)
    index.replace_block(
        1, rng.randn(2, wl.blocks[0].shape[1]).astype(np.float32))
    out = index.apply()
    assert index.stats.full_rebuilds == 1
    assert index.stats.last_tiles == index.stats.tiles_full
    assert wl.equal(out, scratch_fold(wl))


def test_delta_index_max_dirty_knob(monkeypatch):
    P = 5
    plc = get_placement("cyclic", P)
    wl = churn_workload(DenseReduceWorkload, P, seed=0)
    monkeypatch.setenv("REPRO_DELTA_MAX_DIRTY_PCT", "0")
    index = DeltaIndex(wl, plc)
    assert index.max_dirty_pct == 0
    monkeypatch.setenv("REPRO_DELTA_MAX_DIRTY_PCT", "150")
    with pytest.raises(ValueError, match="max_dirty_pct"):
        DeltaIndex(churn_workload(DenseReduceWorkload, P, seed=0), plc)


def test_delta_index_validates_inputs():
    P = 5
    plc = get_placement("cyclic", P)
    wl = churn_workload(DenseReduceWorkload, P, seed=0)
    index = DeltaIndex(wl, plc)
    dim = wl.blocks[0].shape[1]
    with pytest.raises(ValueError, match="mode"):
        DeltaIndex(churn_workload(DenseReduceWorkload, P, seed=0), plc,
                   mode="auto")
    with pytest.raises(ValueError, match="P="):
        DeltaIndex(churn_workload(DenseReduceWorkload, 4, seed=0), plc)
    with pytest.raises(ValueError, match="at most"):
        index.replace_block(0, np.zeros((index.span_of(0) + 1, dim),
                                        np.float32))
    with pytest.raises(ValueError, match="block data"):
        index.replace_block(0, np.zeros((1, dim + 1), np.float32))
    with pytest.raises(ValueError, match="outside"):
        index.span_of(P)


def test_churn_workload_keeps_global_ids_stable():
    P = 5
    wl = churn_workload(DenseReduceWorkload, P, seed=0, spare=2)
    base = DenseReduceWorkload(P, seed=0)
    spans = [base.blocks[b].shape[0] + 2 for b in range(P)]
    assert wl.offsets == [int(s) for s in np.cumsum([0] + spans[:-1])]
    assert wl.n == sum(spans)
    with pytest.raises(ValueError, match="spare"):
        churn_workload(DenseReduceWorkload, P, spare=-1)


# ---------------------------------------------------------------------------
# the churn-chaos selfcheck entry point (a small slice; CI runs the matrix)
# ---------------------------------------------------------------------------

def test_churn_selfcheck_small_slice():
    n = churn_selfcheck(Ps=(5,), modes=("batched",),
                        placements=("cyclic",), n_updates=2, verbose=False)
    assert n == 3  # three workloads x one placement x one mode


def test_churn_selfcheck_even_P_orbit():
    """Even P exercises the doubly-owned d = P/2 orbit in the round
    grouping; run it through overlap and scan."""
    n = churn_selfcheck(Ps=(4,), modes=("overlap", "scan"),
                        placements=("cyclic",), n_updates=2, verbose=False)
    assert n == 6


def test_churn_selfcheck_env_knobs(monkeypatch):
    monkeypatch.setenv("REPRO_DELTA_UPDATES", "1")
    monkeypatch.setenv("REPRO_DELTA_SEED", "9")
    n = churn_selfcheck(Ps=(4,), modes=("batched",),
                        placements=("cyclic",), verbose=False)
    assert n == 3
    monkeypatch.setenv("REPRO_DELTA_UPDATES", "zero")
    with pytest.raises(ValueError, match="REPRO_DELTA_UPDATES"):
        churn_selfcheck(Ps=(4,), modes=("batched",),
                        placements=("cyclic",), verbose=False)


def test_delta_constants_match_issue_acceptance():
    assert DELTA_P == (4, 5, 7, 8, 12, 13)


# ---------------------------------------------------------------------------
# plan stability — the contract shared with failure recovery
# ---------------------------------------------------------------------------

def test_dirty_tiles_is_canonical_order_subset():
    """The enumerator must emit a contiguous-ordered subset of the
    canonical pair order the workloads fold in — recovery scans built on
    it preserve enumeration order and tie-breaks."""
    P = 8
    wl = DenseReduceWorkload(P, seed=0)
    canon = wl.canonical_pairs()
    tiles = dirty_tiles(None, [2, 6], P=P)
    pos = [canon.index(t) for t in tiles]
    assert pos == sorted(pos)


@pytest.mark.parametrize("name", ["cyclic", "projective"])
def test_reassign_plan_stable_over_dirty_tiles(name):
    """Feeding reassign a dirty_tiles-derived pending list (exactly what
    the fault driver now does) yields the same plan on every call — the
    same sorted candidate tie-breaks as the full-universe path."""
    P = 13
    plc = get_placement(name, P)
    sched = plc.schedule()
    victim = 2
    owners = owner_partition(plc)
    universe = dirty_tiles(plc, plc.residency_sets[victim])
    pending = [t for t in universe if owners[t] == victim]
    assert pending  # the victim owns work inside its residency universe
    plans = [reassign(sched, [victim], placement=plc,
                      pairs={victim: list(pending)}) for _ in range(2)]
    assert plans[0].extra_pairs == plans[1].extra_pairs
    assert plans[0].fetch_pairs == plans[1].fetch_pairs
    replayed = {t for ps in plans[0].extra_pairs.values() for t in ps}
    replayed |= {t for entries in plans[0].fetch_pairs.values()
                 for (t, _b, _src) in entries}
    assert replayed == set(pending)  # nothing dropped, nothing invented


@pytest.mark.parametrize("name", sorted(registered_placements()))
def test_residency_universe_contains_owned_tiles(name):
    """The invariant the fault driver's dirty_tiles recovery scan rests
    on: every tile a device owns has both endpoints — a fortiori one —
    in its residency, so dirty_tiles(residency) covers its lost work."""
    P = _supported_P(name)
    plc = get_placement(name, P)
    owners = owner_partition(plc)
    for d in range(P):
        universe = set(dirty_tiles(plc, plc.residency_sets[d]))
        owned = {t for t, o in owners.items() if o == d}
        assert owned <= universe, (name, d)

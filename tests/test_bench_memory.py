"""bench_memory's quantized-vs-f32 resident-bytes accounting (DESIGN.md
section 17.1): the jax-free formula mirror is pinned against the
engine's own ``corpus_bytes_per_device``, the BENCH_engine.json
``memory`` section has the committed shape with the >= 2x int8
reduction, and the read-modify-write contract between bench_memory and
bench_engine keeps the two writers of that file from clobbering each
other.
"""

import importlib
import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

bench_memory = importlib.import_module("benchmarks.bench_memory")

from repro.core.quant import corpus_bytes_per_device  # noqa: E402
from repro.core.scheduler import build_schedule  # noqa: E402


@pytest.mark.parametrize("mode", ["off", "int8", "bf16"])
@pytest.mark.parametrize("N,d,P", [(4096, 256, 4), (4096, 256, 13),
                                   (1000, 32, 8), (77, 5, 5)])
def test_resident_bytes_mirror_matches_engine(N, d, P, mode):
    """The benchmark's jax-free formula and core/quant's byte accounting
    are the same function — a drift here would make BENCH numbers lie
    about the engine."""
    k = build_schedule(P).k
    assert (bench_memory.quant_resident_bytes(N, d, P, k, mode)
            == corpus_bytes_per_device(N, d, P, k, mode))


def test_quant_memory_stats_shape_and_reduction():
    mem = bench_memory.quant_memory_stats(N=4096, d=256, Ps=(4, 8, 13))
    assert set(mem) == {"N", "d", "per_P"}
    assert set(mem["per_P"]) == {"4", "8", "13"}
    for P, entry in mem["per_P"].items():
        assert entry["k"] == build_schedule(int(P)).k
        assert entry["int8_reduction_x"] >= 2.0, (P, entry)
        assert entry["bf16_reduction_x"] > 1.0
        assert (entry["f32_bytes_per_device"]
                > entry["bf16_bytes_per_device"]
                > entry["int8_bytes_per_device"])


def test_run_read_modify_writes_engine_json(tmp_path, monkeypatch):
    """bench_memory.run only touches the ``memory`` key of
    BENCH_engine.json, preserving everything bench_engine wrote; a
    missing file is created from scratch."""
    target = tmp_path / "BENCH_engine.json"
    monkeypatch.setattr(bench_memory, "ENGINE_JSON", target)
    rows = []
    bench_memory.run(rows)                       # file absent -> created
    obj = json.loads(target.read_text())
    assert set(obj) == {"memory"}
    assert obj["memory"]["per_P"]["8"]["int8_reduction_x"] >= 2.0
    assert any(name.startswith("pcit_memory_P") for name, *_ in rows)
    assert any(name.startswith("quant_memory_P") for name, *_ in rows)

    target.write_text(json.dumps(
        {"timings_s": {"8": {"batched": 1.0}}, "memory": {"stale": True}}))
    bench_memory.run([])                         # file present -> merged
    obj = json.loads(target.read_text())
    assert obj["timings_s"] == {"8": {"batched": 1.0}}   # preserved
    assert "stale" not in obj["memory"]                   # replaced
    assert obj["memory"]["N"] == 4096


def test_bench_engine_carries_memory_key():
    """The other half of the contract: bench_engine.run's full rewrite
    re-reads and carries the ``memory`` section forward (source-level
    pin; running bench_engine spawns minute-long fake-device children,
    so the committed BENCH_engine.json is asserted instead)."""
    committed = json.loads((ROOT / "BENCH_engine.json").read_text())
    assert "memory" in committed, (
        "BENCH_engine.json lost its memory section — bench_engine.run "
        "must carry it across rewrites (see bench_engine.run)")
    assert committed["memory"]["per_P"]["8"]["int8_reduction_x"] >= 2.0

"""Direct tests for the central REPRO_* knob registry (core/env.py).

Every knob's validation (bad values raise with the canonical message),
the unknown-variable typo detection, and the README env-var table's
agreement with the registry.
"""

from pathlib import Path

import pytest

from repro.core import env as env_mod

ROOT = Path(__file__).resolve().parents[1]


def test_unset_and_empty_mean_no_override(monkeypatch):
    for name in env_mod.ENV_KNOBS:
        monkeypatch.delenv(name, raising=False)
        assert env_mod.read_knob(name) is None
        monkeypatch.setenv(name, "   ")
        assert env_mod.read_knob(name) is None


def test_mode_knob_validation(monkeypatch):
    monkeypatch.setenv("REPRO_ALLPAIRS_MODE", "OVERLAP")   # case-folded
    assert env_mod.read_knob("REPRO_ALLPAIRS_MODE") == "overlap"
    monkeypatch.setenv("REPRO_ALLPAIRS_MODE", "fastest")
    with pytest.raises(ValueError, match="REPRO_ALLPAIRS_MODE must be one"):
        env_mod.read_knob("REPRO_ALLPAIRS_MODE")
    # the reader everyone actually calls surfaces the same error
    from repro.core.sweep import env_mode_override
    with pytest.raises(ValueError, match="REPRO_ALLPAIRS_MODE"):
        env_mode_override()


def test_placement_knob_validation(monkeypatch):
    monkeypatch.setenv("REPRO_PLACEMENT", "plane")
    assert env_mod.read_knob("REPRO_PLACEMENT") == "plane"
    monkeypatch.setenv("REPRO_PLACEMENT", "hexagonal")
    with pytest.raises(ValueError, match="REPRO_PLACEMENT must be one"):
        env_mod.read_knob("REPRO_PLACEMENT")


def test_int_knob_validation(monkeypatch):
    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "4096")
    assert env_mod.read_knob("REPRO_BATCH_BYTES_LIMIT") == 4096
    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "lots")
    with pytest.raises(ValueError, match="must be an integer"):
        env_mod.read_knob("REPRO_BATCH_BYTES_LIMIT")
    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "0")
    with pytest.raises(ValueError, match="must be >= 1"):
        env_mod.read_knob("REPRO_BATCH_BYTES_LIMIT")
    # the shared budget reader raises too (no silent fallthrough)
    from repro.core.sweep import auto_batch_bytes
    with pytest.raises(ValueError, match="REPRO_BATCH_BYTES_LIMIT"):
        auto_batch_bytes()
    monkeypatch.setenv("REPRO_SPARSE_CAPACITY", "-3")
    with pytest.raises(ValueError, match="REPRO_SPARSE_CAPACITY must be >="):
        env_mod.read_knob("REPRO_SPARSE_CAPACITY")


def test_trace_knob_passthrough(monkeypatch):
    """REPRO_TRACE is a str knob: any non-empty value passes through
    verbatim (case preserved — it may be a filesystem path)."""
    monkeypatch.setenv("REPRO_TRACE", "1")
    assert env_mod.read_knob("REPRO_TRACE") == "1"
    monkeypatch.setenv("REPRO_TRACE", "/Traces/Run7.json")
    assert env_mod.read_knob("REPRO_TRACE") == "/Traces/Run7.json"
    monkeypatch.setenv("REPRO_METRICS", "1")
    assert env_mod.read_knob("REPRO_METRICS") == 1
    monkeypatch.setenv("REPRO_METRICS", "-1")
    with pytest.raises(ValueError, match="REPRO_METRICS must be >= 0"):
        env_mod.read_knob("REPRO_METRICS")


def test_unknown_knob_typo_detection(monkeypatch):
    """A REPRO_* variable matching no registered knob warns once, naming
    the closest registered knob."""
    monkeypatch.delenv("REPRO_ALLPAIRS_MODE", raising=False)
    monkeypatch.setenv("REPRO_ALLPAIRS_MODES", "scan")     # trailing S
    monkeypatch.setattr(env_mod, "_warned_unknown", set())
    with pytest.warns(RuntimeWarning,
                      match="did you mean REPRO_ALLPAIRS_MODE"):
        env_mod.read_knob("REPRO_ALLPAIRS_MODE")
    # warned once per process, not on every read
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        env_mod.read_knob("REPRO_ALLPAIRS_MODE")


def test_unknown_knob_warns_once_across_reads(monkeypatch):
    """Regression (ISSUE 7 satellite): the typo warning fires exactly
    once per unknown variable per process, even with an ``always``
    warning filter, across repeated reads of multiple knobs — and a
    variable that appears later still gets its own single warning."""
    import warnings as _w
    monkeypatch.delenv("REPRO_ALLPAIRS_MODE", raising=False)
    monkeypatch.delenv("REPRO_PLACEMENT", raising=False)
    monkeypatch.setenv("REPRO_ALLPAIRS_MODES", "scan")      # trailing S
    monkeypatch.setattr(env_mod, "_warned_unknown", set())
    monkeypatch.setattr(env_mod, "_seen_env_keys", frozenset())
    with _w.catch_warnings(record=True) as caught:
        _w.simplefilter("always")
        for _ in range(5):
            env_mod.read_knob("REPRO_ALLPAIRS_MODE")
            env_mod.read_knob("REPRO_PLACEMENT")
        hits = [c for c in caught if "REPRO_ALLPAIRS_MODES" in
                str(c.message)]
        assert len(hits) == 1, [str(c.message) for c in caught]
        # an unknown variable set later still warns (exactly once)
        monkeypatch.setenv("REPRO_PLACEMENTT", "plane")     # trailing T
        for _ in range(3):
            env_mod.read_knob("REPRO_PLACEMENT")
        late = [c for c in caught if "REPRO_PLACEMENTT" in str(c.message)]
        assert len(late) == 1, [str(c.message) for c in caught]


def test_registry_is_documented():
    """Every knob carries a description, describe_knobs() renders all of
    them, and the README env-var table names each registered knob."""
    text = env_mod.describe_knobs()
    readme = (ROOT / "README.md").read_text()
    for name, knob in env_mod.ENV_KNOBS.items():
        assert knob.description.strip(), name
        assert name in text
        assert name in readme, f"{name} missing from the README env table"


def test_choice_lists_are_live():
    """Choice knobs resolve their valid values lazily, so placements
    registered after import join validation automatically."""
    modes = env_mod.ENV_KNOBS["REPRO_ALLPAIRS_MODE"].choices()
    assert modes == ("batched", "overlap", "scan")
    placements = env_mod.ENV_KNOBS["REPRO_PLACEMENT"].choices()
    assert "auto" in placements and "plane" in placements
    assert "cyclic" in placements and "full" in placements

"""Direct tests for the central REPRO_* knob registry (core/env.py).

Every knob's validation (bad values raise with the canonical message),
the unknown-variable typo detection, and the README env-var table's
agreement with the registry.
"""

from pathlib import Path

import pytest

from repro.core import env as env_mod

ROOT = Path(__file__).resolve().parents[1]


def test_unset_and_empty_mean_no_override(monkeypatch):
    for name in env_mod.ENV_KNOBS:
        monkeypatch.delenv(name, raising=False)
        assert env_mod.read_knob(name) is None
        monkeypatch.setenv(name, "   ")
        assert env_mod.read_knob(name) is None


def test_mode_knob_validation(monkeypatch):
    monkeypatch.setenv("REPRO_ALLPAIRS_MODE", "OVERLAP")   # case-folded
    assert env_mod.read_knob("REPRO_ALLPAIRS_MODE") == "overlap"
    monkeypatch.setenv("REPRO_ALLPAIRS_MODE", "fastest")
    with pytest.raises(ValueError, match="REPRO_ALLPAIRS_MODE must be one"):
        env_mod.read_knob("REPRO_ALLPAIRS_MODE")
    # the reader everyone actually calls surfaces the same error
    from repro.core.sweep import env_mode_override
    with pytest.raises(ValueError, match="REPRO_ALLPAIRS_MODE"):
        env_mode_override()


def test_placement_knob_validation(monkeypatch):
    monkeypatch.setenv("REPRO_PLACEMENT", "plane")
    assert env_mod.read_knob("REPRO_PLACEMENT") == "plane"
    monkeypatch.setenv("REPRO_PLACEMENT", "hexagonal")
    with pytest.raises(ValueError, match="REPRO_PLACEMENT must be one"):
        env_mod.read_knob("REPRO_PLACEMENT")


def test_int_knob_validation(monkeypatch):
    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "4096")
    assert env_mod.read_knob("REPRO_BATCH_BYTES_LIMIT") == 4096
    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "lots")
    with pytest.raises(ValueError, match="must be an integer"):
        env_mod.read_knob("REPRO_BATCH_BYTES_LIMIT")
    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "0")
    with pytest.raises(ValueError, match="must be >= 1"):
        env_mod.read_knob("REPRO_BATCH_BYTES_LIMIT")
    # the shared budget reader raises too (no silent fallthrough)
    from repro.core.sweep import auto_batch_bytes
    with pytest.raises(ValueError, match="REPRO_BATCH_BYTES_LIMIT"):
        auto_batch_bytes()
    monkeypatch.setenv("REPRO_SPARSE_CAPACITY", "-3")
    with pytest.raises(ValueError, match="REPRO_SPARSE_CAPACITY must be >="):
        env_mod.read_knob("REPRO_SPARSE_CAPACITY")


def test_unknown_knob_typo_detection(monkeypatch):
    """A REPRO_* variable matching no registered knob warns once, naming
    the closest registered knob."""
    monkeypatch.delenv("REPRO_ALLPAIRS_MODE", raising=False)
    monkeypatch.setenv("REPRO_ALLPAIRS_MODES", "scan")     # trailing S
    monkeypatch.setattr(env_mod, "_warned_unknown", set())
    with pytest.warns(RuntimeWarning,
                      match="did you mean REPRO_ALLPAIRS_MODE"):
        env_mod.read_knob("REPRO_ALLPAIRS_MODE")
    # warned once per process, not on every read
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        env_mod.read_knob("REPRO_ALLPAIRS_MODE")


def test_registry_is_documented():
    """Every knob carries a description, describe_knobs() renders all of
    them, and the README env-var table names each registered knob."""
    text = env_mod.describe_knobs()
    readme = (ROOT / "README.md").read_text()
    for name, knob in env_mod.ENV_KNOBS.items():
        assert knob.description.strip(), name
        assert name in text
        assert name in readme, f"{name} missing from the README env table"


def test_choice_lists_are_live():
    """Choice knobs resolve their valid values lazily, so placements
    registered after import join validation automatically."""
    modes = env_mod.ENV_KNOBS["REPRO_ALLPAIRS_MODE"].choices()
    assert modes == ("batched", "overlap", "scan")
    placements = env_mod.ENV_KNOBS["REPRO_PLACEMENT"].choices()
    assert "auto" in placements and "plane" in placements
    assert "cyclic" in placements and "full" in placements

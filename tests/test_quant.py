"""Quantized scoring path tests (DESIGN.md section 17).

The acceptance sweep: ``repro.core.quant`` selfcheck — the rescored
join, k-NN graph, and serving query must be **bit-identical** to the
f32 oracles across every execution mode (batched / overlap / scan /
fused kernel), both metrics, including after a streamed serving block
replace — for **every registered placement** at P in {4, 5, 7, 8, 12,
13} where the placement is defined (the test_sparse.py sweep, extended
to the quantized pipeline).  The parametrized sweep pins the CI
placement-matrix cell's configuration (``REPRO_QUANT=int8``); anchor
cases cover bf16 and the both-qmodes default.  Runs in fake-device
subprocesses (dry-run isolation rule, see tests/test_distributed.py).

Host-level pieces — the per-block quantizer's error contract, the
certified eps bounds, the byte accounting, and the ``REPRO_QUANT``
routing of the public workload entry points — are covered in-process
or in a single small subprocess.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.placement import registered_placements
from repro.core.quant import (corpus_bytes_per_device, eps_pairs,
                              quant_itemsize, quantize_corpus)

SRC = Path(__file__).resolve().parents[1] / "src"

P_SWEEP = (4, 5, 7, 8, 12, 13)

QUANT_CASES = [
    (P, name)
    for P in P_SWEEP
    for name, cls in sorted(registered_placements().items())
    if cls.supports(P)
]


def run_sub(code: str, devices: int, env_extra: dict | None = None) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    env.update(env_extra or {})
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("P,name", QUANT_CASES,
                         ids=[f"{n}-P{P}" for P, n in QUANT_CASES])
def test_quant_selfcheck_matches_oracle(P, name):
    """The CI-cell configuration: REPRO_QUANT=int8 restricts the swept
    quant modes (the knob's routing is itself under test) and the
    selfcheck asserts bit-exact join / k-NN / serving results across
    every engine mode plus the fused kernel."""
    out = run_sub(
        f"from repro.core.quant import selfcheck_main; "
        f"selfcheck_main({P}, placement={name!r})", P,
        env_extra={"REPRO_QUANT": "int8"})
    assert "quant selfcheck OK" in out
    assert f"P={P} placement={name}" in out
    assert "quant=int8 " in out
    assert "modes=batched,overlap,scan,kernel" in out


def test_quant_selfcheck_bf16_anchor():
    """bf16 through the same full-mode selfcheck (one anchor — the
    parametrized sweep runs int8, the cheaper and tighter-band mode)."""
    out = run_sub(
        "from repro.core.quant import selfcheck_main; "
        "selfcheck_main(8, placement='cyclic')", 8,
        env_extra={"REPRO_QUANT": "bf16"})
    assert "quant selfcheck OK" in out
    assert "quant=bf16 " in out


def test_quant_selfcheck_default_sweeps_both_modes():
    """Without REPRO_QUANT the selfcheck sweeps both quant modes."""
    out = run_sub(
        "from repro.core.quant import selfcheck_main; "
        "selfcheck_main(4, modes=('batched', 'scan'))", 4)
    assert "quant selfcheck OK" in out
    assert "quant=int8,bf16 " in out


def test_env_quant_routing():
    """REPRO_QUANT=int8 routes the public f32 entry points
    (similarity_join / knn_graph / ServingCorpus) through the quantized
    pipeline with bit-identical results, and ``quant='off'`` opts back
    out per call (DESIGN.md section 17.5)."""
    code = """
import numpy as np, jax
from repro.core.sparse import (brute_force_join, similarity_join,
                               threshold_for_selectivity)
from repro.core.knn import brute_force_knn, knn_graph
from repro.serving.engine import ServingCorpus

rng = np.random.default_rng(5)
corpus = rng.normal(size=(45, 12)).astype(np.float32)
thr = threshold_for_selectivity(corpus, 0.1)
mesh = jax.make_mesh((4,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))

res = similarity_join(corpus, mesh, threshold=thr)      # env -> int8 path
wi, wj, ws = brute_force_join(corpus, thr)
assert np.array_equal(res.i, wi) and np.array_equal(res.j, wj)
assert np.allclose(res.scores, ws, rtol=1e-6, atol=1e-5)  # f32 rescore
off = similarity_join(corpus, mesh, threshold=thr, quant="off")
assert np.array_equal(off.i, wi) and np.array_equal(off.j, wj)

g = knn_graph(corpus, mesh, topk=3)                     # env -> int8 path
ref = brute_force_knn(corpus, 3)
assert np.array_equal(g.indices, ref.indices)
assert np.array_equal(knn_graph(corpus, mesh, topk=3, quant="off").indices,
                      ref.indices)

sc = ServingCorpus.build(corpus, mesh)                  # env -> int8 path
assert sc.quant is not None
vals, idx = sc.query(corpus[:3] * 0.9, topk=3)
rq = brute_force_knn(corpus, 3)  # queries are scaled rows: just sanity
assert idx.shape == (3, 3)
off_sc = ServingCorpus.build(corpus, mesh, quant="off")
assert off_sc.quant is None
ov, oi = off_sc.query(corpus[:3] * 0.9, topk=3)
assert np.array_equal(idx, oi)
assert np.allclose(vals, ov, rtol=1e-6, atol=1e-5)
print("QUANT-ENV-OK")
"""
    out = run_sub(code, 4, env_extra={"REPRO_QUANT": "int8"})
    assert "QUANT-ENV-OK" in out


def test_quantize_corpus_error_contract():
    """Per-block symmetric int8: reconstruction error of every element
    is within the block's certified delta; bf16 within maxabs * 2^-8;
    all-zero blocks get scale 1 / delta 0 (no NaNs, exact zeros)."""
    rng = np.random.default_rng(0)
    P, block, d = 4, 8, 6
    x = rng.normal(size=(P * block, d)).astype(np.float32)
    x[:block] *= 0.01                       # small-scale block
    x[block:2 * block] = 0.0                # all-zero block
    for mode in ("int8", "bf16"):
        qc = quantize_corpus(x, P, block, mode)
        assert qc.scale.shape == (P,) and qc.delta.shape == (P,)
        assert qc.delta[1] == 0.0 and qc.scale[1] == 1.0
        deq = np.zeros_like(x)
        for b in range(P):
            rows = slice(b * block, (b + 1) * block)
            deq[rows] = (np.asarray(qc.q[rows], np.float32)
                         * float(qc.scale[b]))
            err = np.abs(deq[rows] - x[rows])
            assert err.max() <= float(qc.delta[b]) + 1e-12, (mode, b)
        assert np.all(deq[block:2 * block] == 0.0)
        # side arrays are exact f32 stats of the ORIGINAL rows
        np.testing.assert_allclose(qc.l1, np.abs(x).sum(1), rtol=1e-6)
        np.testing.assert_allclose(qc.sq, (x * x).sum(1), rtol=1e-6)


@pytest.mark.parametrize("mode", ["int8", "bf16"])
@pytest.mark.parametrize("metric", ["dot", "l2"])
def test_eps_pairs_bounds_true_error(mode, metric):
    """The certified bound: |score_q - score_f32| <= eps(i, j) for the
    host mirror of the device scoring formula, on data with mixed
    per-block scales."""
    rng = np.random.default_rng(2)
    P, block, d = 5, 16, 24
    x = rng.normal(size=(P * block, d)).astype(np.float32)
    x[:block] *= 0.03
    x[2 * block:3 * block] *= 40.0
    qc = quantize_corpus(x, P, block, mode)
    deq = np.zeros_like(x)
    for b in range(P):
        rows = slice(b * block, (b + 1) * block)
        deq[rows] = np.asarray(qc.q[rows], np.float32) * float(qc.scale[b])
    ai = rng.integers(0, P * block, 400).astype(np.int64)
    aj = rng.integers(0, P * block, 400).astype(np.int64)
    if metric == "dot":
        s_f = np.einsum("nd,nd->n", x[ai], x[aj])
        s_q = np.einsum("nd,nd->n", deq[ai], deq[aj])
    else:
        n2 = (x * x).sum(1)
        dots_f = np.einsum("nd,nd->n", x[ai], x[aj])
        dots_q = np.einsum("nd,nd->n", deq[ai], deq[aj])
        s_f = (2.0 * dots_f - n2[aj]) - n2[ai]
        s_q = (2.0 * dots_q - n2[aj]) - n2[ai]
    eps = eps_pairs(qc, ai, aj, metric)
    assert np.all(np.abs(s_q - s_f) <= eps), (
        mode, metric, float(np.max(np.abs(s_q - s_f) - eps)))
    assert np.all(eps > 0)


def test_quant_itemsize_and_bytes():
    assert quant_itemsize("int8") == 1
    assert quant_itemsize("bf16") == 2
    assert quant_itemsize("off") == 4
    with pytest.raises(ValueError, match="quant mode"):
        quant_itemsize("fp8")
    # int8 resident bytes clear the >=2x reduction bar at every swept P
    for P in P_SWEEP:
        from repro.core.scheduler import build_schedule
        k = build_schedule(P).k
        f32 = corpus_bytes_per_device(4096, 128, P, k, "off")
        i8 = corpus_bytes_per_device(4096, 128, P, k, "int8")
        assert f32 / i8 >= 2.0, (P, f32 / i8)


def test_bad_quant_value_rejected():
    """Both the env knob and the explicit argument reject unknown
    modes."""
    code = """
import numpy as np, jax, pytest, warnings
from repro.core.sparse import similarity_join
corpus = np.eye(8, 4, dtype=np.float32)
mesh = jax.make_mesh((4,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
try:
    similarity_join(corpus, mesh, threshold=0.5, quant="fp4")
except ValueError as e:
    assert "quant" in str(e), e
else:
    raise AssertionError("unknown quant mode must raise")
print("QUANT-REJECT-OK")
"""
    out = run_sub(code, 4)
    assert "QUANT-REJECT-OK" in out

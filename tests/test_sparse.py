"""Thresholded sparse similarity-join tests (DESIGN.md section 11).

The acceptance sweep: ``repro.core.sparse`` selfcheck — bit-exact
pair-set equality (index-level) against the dense brute-force oracle for
every execution mode (batched / overlap / scan / fused kernel), both
metrics, prefilter on and off, plus the overflow/escalation contract and
the ppermute ring gather — for **every registered placement** at
P in {4, 5, 7, 8, 12, 13} where the placement is defined (the
test_placement_engine.py sweep, extended to the sparse engine).  Runs in
fake-device subprocesses (dry-run isolation rule, see
tests/test_distributed.py).  The serving-side thresholded range query is
swept by the serving selfcheck in test_serving.py / test_placement_engine
sweeps, which now include ``check_threshold``.

Host-level helpers (threshold selection, the brute-force oracle, the
capacity heuristic + env override) are covered in-process.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.placement import registered_placements
from repro.core.sparse import (brute_force_join, default_capacity,
                               threshold_for_selectivity)

SRC = Path(__file__).resolve().parents[1] / "src"

P_SWEEP = (4, 5, 7, 8, 12, 13)

SPARSE_CASES = [
    (P, name)
    for P in P_SWEEP
    for name, cls in sorted(registered_placements().items())
    if cls.supports(P)
]


def run_sub(code: str, devices: int, env_extra: dict | None = None) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    env.update(env_extra or {})
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("P,name", SPARSE_CASES,
                         ids=[f"{n}-P{P}" for P, n in SPARSE_CASES])
def test_sparse_join_matches_oracle(P, name):
    """Every mode + fused kernel under the placement returns the exact
    passing-pair index set of the dense oracle; overflow flags, capacity
    escalation, and the ring gather are asserted inside the selfcheck."""
    out = run_sub(
        f"from repro.core.sparse import selfcheck_main; "
        f"selfcheck_main({P}, placement={name!r})", P)
    assert "sparse selfcheck OK" in out
    assert f"placement={name}(" in out
    assert "batched,overlap,scan,kernel" in out


def test_sparse_env_mode_override():
    """REPRO_ALLPAIRS_MODE steers the sparse engine's auto mode (shared
    override surface, DESIGN.md section 4): a forced mode still matches
    the oracle, and a conflict with the fused kernel raises."""
    code = """
import numpy as np, jax
from repro.core.sparse import (brute_force_join, similarity_join,
                               threshold_for_selectivity)
rng = np.random.default_rng(3)
corpus = rng.normal(size=(40, 8)).astype(np.float32)
thr = threshold_for_selectivity(corpus, 0.1)
mesh = jax.make_mesh((4,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
res = similarity_join(corpus, mesh, threshold=thr)   # auto -> forced scan
wi, wj, _ = brute_force_join(corpus, thr)
assert (res.i == wi).all() and (res.j == wj).all()
try:
    similarity_join(corpus, mesh, threshold=thr, use_kernel=True)
except ValueError as e:
    assert "conflicts with a fused batch_fn" in str(e), e
else:
    raise AssertionError("kernel + forced non-batched mode must raise")
print("SPARSE-ENV-OK")
"""
    out = run_sub(code, 4, env_extra={"REPRO_ALLPAIRS_MODE": "scan"})
    assert "SPARSE-ENV-OK" in out


def test_serving_threshold_placement():
    """The serving range query under a plane placement (the
    check_threshold step of the serving selfcheck at projective P=7)."""
    out = run_sub(
        "from repro.serving.selfcheck import main; "
        "main(7, placement='projective')", 7)
    assert "serving selfcheck OK" in out
    assert "placement=projective(" in out


def test_brute_force_join_properties():
    rng = np.random.default_rng(0)
    corpus = rng.normal(size=(30, 6)).astype(np.float32)
    for metric in ("dot", "l2"):
        thr = threshold_for_selectivity(corpus, 0.2, metric)
        i, j, s = brute_force_join(corpus, thr, metric)
        assert (i < j).all()
        assert (s >= thr).all()
        # sorted by (i, j), no duplicates
        order = np.lexsort((j, i))
        assert (order == np.arange(len(i))).all()
        assert len({(a, b) for a, b in zip(i.tolist(), j.tolist())}) == len(i)
        # selectivity lands near the target
        total = corpus.shape[0] * (corpus.shape[0] - 1) // 2
        assert 0.1 <= len(i) / total <= 0.3, len(i) / total


def test_threshold_for_selectivity_gap():
    """The picked threshold sits strictly inside a score gap, so no score
    lies within min_gap/2 of it — float-rounding-proof membership."""
    rng = np.random.default_rng(1)
    corpus = rng.normal(size=(24, 5)).astype(np.float32)
    thr = threshold_for_selectivity(corpus, 0.15, "dot", min_gap=1e-3)
    _, _, s = brute_force_join(corpus, -np.inf, "dot")
    assert (np.abs(s - thr) > 5e-4).all()


def test_default_capacity_env(monkeypatch):
    monkeypatch.delenv("REPRO_SPARSE_CAPACITY", raising=False)
    assert default_capacity(1) == 128                  # floor
    assert default_capacity(1 << 20) == (1 << 20) // 8  # 1/8, already x128
    assert default_capacity(1000) == 128               # ceil(125) -> 128
    monkeypatch.setenv("REPRO_SPARSE_CAPACITY", "512")
    assert default_capacity(1 << 30) == 512            # override wins
    monkeypatch.setenv("REPRO_SPARSE_CAPACITY", "0")
    with pytest.raises(ValueError, match="REPRO_SPARSE_CAPACITY"):
        default_capacity(1)

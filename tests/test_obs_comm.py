"""Comm-volume predictor (obs/comm.py, DESIGN.md section 14.3): host-side
unit tests for the analytical formulas, plus the predictor-vs-traced
equality check at P in {5, 8, 13} across every registered placement
(subprocess fake-device runs of ``python -m repro.obs.comm``).
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core.placement import get_placement
from repro.obs import trace as trace_mod
from repro.obs.comm import (block_bytes_of, predict_ring_gather_comm,
                            predict_sweep_comm, predict_tree_merge_comm,
                            quant_block_bytes, traced_sweep_comm)

SRC = Path(__file__).resolve().parents[1] / "src"


def run_sub(argv, devices, timeout=600):
    """Run ``python -m <argv>`` under `devices` fake XLA devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-m"] + argv, env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, (
        f"exit {r.returncode}\nstdout:\n{r.stdout}\nstderr:\n{r.stderr}")
    return r.stdout


def test_predict_cyclic_counts_nonzero_shifts():
    """gather moves one block per nonzero shift; scatter returns one
    partial per nonzero shift — the paper's O(N/sqrt(P)) replication
    made concrete in bytes."""
    plc = get_placement("cyclic", 8)
    sched = plc.schedule()
    nz = int(sum(1 for a in sched.shifts if a % 8 != 0))
    c = predict_sweep_comm(plc, block_bytes=1000, partial_bytes=300)
    assert c.gather_hops == nz and c.scatter_hops == nz
    assert c.gather_bytes == nz * 1000
    assert c.scatter_bytes == nz * 300
    assert c.allgather_bytes == 0
    assert c.ppermute_bytes == c.gather_bytes + c.scatter_bytes
    assert c.resident_bytes == plc.replication * 1000


def test_predict_partial_bytes_defaults_to_block_bytes():
    c = predict_sweep_comm(get_placement("cyclic", 5), block_bytes=64)
    assert c.partial_bytes == 64
    assert c.gather_bytes == c.scatter_bytes


def test_predict_full_placement_is_allgather():
    c = predict_sweep_comm(get_placement("full", 8), block_bytes=100)
    assert c.gather_hops == 0 and c.scatter_hops == 0
    assert c.ppermute_bytes == 0
    assert c.allgather_bytes == (8 - 1) * 100
    assert c.resident_bytes == 8 * 100


def test_predict_accepts_name_with_P():
    c = predict_sweep_comm("cyclic", block_bytes=10, P=13)
    assert c.P == 13 and c.placement == "cyclic"
    with pytest.raises(ValueError):
        predict_sweep_comm("cyclic", block_bytes=10)  # name needs P


def test_predict_as_dict_roundtrip():
    c = predict_sweep_comm(get_placement("cyclic", 5), block_bytes=48)
    d = c.as_dict()
    assert d["gather_bytes"] == c.gather_bytes
    assert d["placement"] == "cyclic" and d["P"] == 5


@pytest.mark.parametrize("P,hops", [(1, 0), (2, 1), (8, 3), (13, 4)])
def test_predict_tree_merge_hops(P, hops):
    c = predict_tree_merge_comm(P, payload_bytes=100)
    assert c["hops"] == hops
    assert c["bytes"] == hops * 100


def test_predict_ring_gather():
    c = predict_ring_gather_comm(8, payload_bytes=50)
    assert c["hops"] == 7
    assert c["bytes"] == 7 * 50


def test_traced_sweep_comm_reads_counters():
    tr = trace_mod.Tracer(metrics_only=True)
    tr.count("comm.ppermute.gather_bytes", 128)
    tr.count("comm.ppermute.scatter_bytes", 96)
    tr.count("comm.ppermute.gather_hops", 2)
    tr.count("comm.ppermute.scatter_hops", 2)
    got = traced_sweep_comm(tr)
    assert got == {"gather_bytes": 128, "scatter_bytes": 96,
                   "gather_hops": 2, "scatter_hops": 2,
                   "allgather_bytes": 0}


@pytest.mark.parametrize("P", [5, 8, 13])
def test_predictor_matches_traced_all_placements(P):
    """ISSUE 7 acceptance: for every registered placement defined at P,
    the traced ppermute/allgather bytes of a real dense sweep equal the
    analytical prediction exactly.  verify_dense_comm asserts equality
    per placement and prints one OK line per placement checked."""
    out = run_sub(["repro.obs.comm", "--P", str(P)], devices=P)
    assert "comm predictor OK" in out, out
    assert f"P={P}" in out


def test_block_bytes_of_itemsize():
    """The predictor's dtype parametrization: payload bytes scale with
    the element itemsize (DESIGN.md section 17.3)."""
    assert block_bytes_of(4, 3) == 4 * 3 * 4
    assert block_bytes_of(4, 3, "bfloat16") == 4 * 3 * 2
    assert block_bytes_of(4, 3, "int8") == 4 * 3
    assert block_bytes_of(7, 5, "float64") == 7 * 5 * 8


def test_quant_block_bytes_counts_side_arrays():
    """The quantized gather payload = codes at the quant itemsize plus
    the per-block scale/delta f32 scalars (8 B) and the per-row f32
    l1/sq side arrays (8 B per row) — the eps bound rides the gather
    (DESIGN.md section 17.3)."""
    block, dim = 6, 10
    assert quant_block_bytes(block, dim, "int8") == block * dim + 8 + 8 * block
    assert (quant_block_bytes(block, dim, "bf16")
            == block * dim * 2 + 8 + 8 * block)
    with pytest.raises(ValueError):
        quant_block_bytes(block, dim, "fp4")


@pytest.mark.parametrize("P,dtype", [(5, "bfloat16"), (8, "int8")])
def test_predictor_matches_traced_nondefault_dtype(P, dtype):
    """The dense predictor stays exact when the swept payload is not
    f32: traced bytes == nz * block * dim * itemsize."""
    out = run_sub(["repro.obs.comm", "--P", str(P), "--dtype", dtype],
                  devices=P)
    assert "comm predictor OK" in out, out
    assert f"dtype={dtype}" in out


@pytest.mark.parametrize("P,qmode", [(5, "int8"), (8, "bf16"), (13, "int8")])
def test_quant_predictor_matches_traced(P, qmode):
    """The quantized-stack gather (a 5-leaf QuantBlocks pytree through
    quorum_gather) moves exactly nz * quant_block_bytes per device —
    the predictor and the trace counters agree for every placement
    defined at P (DESIGN.md section 17.3)."""
    out = run_sub(["repro.obs.comm", "--P", str(P), "--quant", qmode],
                  devices=P)
    assert "quant comm predictor OK" in out, out
    assert f"quant={qmode}" in out

"""Unit tests for the unified pair-sweep runtime (core/sweep.py,
DESIGN.md section 12).

The engine selfchecks prove end-to-end equality per workload; this file
pins the runtime's own contracts: the single mode heuristic and its env
override / fused-kernel conflicts, the argument validation every adapter
shares, the work-item ready order, and the emitter protocol conformance
of all five shipped emitters.
"""

import numpy as np
import pytest

from repro.core import sweep
from repro.core.scheduler import build_schedule


def test_validate_mode_contract():
    sweep.validate_mode("auto", None)
    sweep.validate_mode("batched", object())
    with pytest.raises(ValueError, match="mode must be one of"):
        sweep.validate_mode("fastest", None)
    with pytest.raises(ValueError, match="batch_fn only replaces"):
        sweep.validate_mode("scan", object())
    with pytest.raises(ValueError, match="batch_fn only replaces"):
        sweep.validate_mode("overlap", object())


def test_select_mode_policy(monkeypatch):
    """The single auto heuristic: env override first (kernel conflicts
    raise), fused kernel -> batched, byte budget -> batched, k >= 3 ->
    overlap, else scan."""
    monkeypatch.delenv("REPRO_ALLPAIRS_MODE", raising=False)
    monkeypatch.delenv("REPRO_BATCH_BYTES_LIMIT", raising=False)
    sched = build_schedule(8)           # k = 4
    assert sweep.select_mode(sched, 64, None) == "batched"
    assert sweep.select_mode(sched, 10 ** 12, None) == "overlap"
    assert sweep.select_mode(sched, 10 ** 12, object()) == "batched"
    sched2 = build_schedule(2)          # k = 2: nothing to hide behind
    assert sweep.select_mode(sched2, 10 ** 12, None) == "scan"

    monkeypatch.setenv("REPRO_ALLPAIRS_MODE", "overlap")
    assert sweep.select_mode(sched, 64, None) == "overlap"
    with pytest.raises(ValueError, match="conflicts with a fused batch_fn"):
        sweep.select_mode(sched, 64, object())
    monkeypatch.setenv("REPRO_ALLPAIRS_MODE", "batched")
    assert sweep.select_mode(sched, 10 ** 12, object()) == "batched"

    # the budget is read at selection time (not import time)
    monkeypatch.delenv("REPRO_ALLPAIRS_MODE", raising=False)
    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "65")
    assert sweep.select_mode(sched, 65, None) == "batched"
    assert sweep.select_mode(sched, 66, None) == "overlap"


def test_engine_working_sets_share_the_policy(monkeypatch):
    """Each engine's _select_mode shim feeds its own working-set formula
    into the one shared policy — shrinking the budget steers all of
    them at once."""
    import jax.numpy as jnp

    from repro.core import allpairs as ap
    from repro.core import knn as knn_mod
    from repro.core import sparse as sp
    from repro.serving import engine as se

    monkeypatch.delenv("REPRO_ALLPAIRS_MODE", raising=False)
    sched = build_schedule(8)
    x = jnp.zeros((16, 8), jnp.float32)
    probe = jnp.zeros((16, 8), jnp.float32)
    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", str(1 << 30))
    assert ap._select_mode(sched, x, probe, None) == "batched"
    assert sp._select_mode(sched, 16, None) == "batched"
    assert se._select_mode(sched, x, 16, None) == "batched"
    assert knn_mod._select_mode(sched, 16, None) == "batched"
    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "1")
    assert ap._select_mode(sched, x, probe, None) == "overlap"
    assert sp._select_mode(sched, 16, None) == "overlap"
    assert se._select_mode(sched, x, 16, None) == "overlap"
    assert knn_mod._select_mode(sched, 16, None) == "overlap"


def test_ready_order_pairs_and_slots():
    sched = build_schedule(8)
    ready = sweep.pair_ready_order(sched)
    assert len(ready) == sched.k
    # every pair appears exactly once, at the slot of its later block
    seen = sorted(i for slot in ready for i in slot)
    assert seen == list(range(sched.n_pairs))
    for s, idxs in enumerate(ready):
        for i in idxs:
            assert max(sched.pair_slots[i]) == s
    # slot sweeps are their own ready order: item s at slot s
    lo, hi = sweep.slot_items(5)
    assert sweep.ready_order(lo, hi, 5) == [[0], [1], [2], [3], [4]]


def test_all_emitters_conform():
    """Every shipped workload emitter subclasses SweepEmitter with all
    abstract methods implemented (instantiable protocol conformance)."""
    from repro.core.allpairs import DenseReduceEmitter
    from repro.core.knn import KnnEmitter
    from repro.core.sparse import ThresholdJoinEmitter
    from repro.serving.engine import QueryThresholdEmitter, QueryTopKEmitter

    for cls in (DenseReduceEmitter, ThresholdJoinEmitter, QueryTopKEmitter,
                QueryThresholdEmitter, KnnEmitter):
        assert issubclass(cls, sweep.SweepEmitter), cls
        assert not getattr(cls, "__abstractmethods__", None), cls


def test_pair_sweep_requires_one_source():
    from repro.core.allpairs import DenseReduceEmitter

    sched = build_schedule(4)
    emitter = DenseReduceEmitter(lambda a, b: (a, b), sched,
                                 np.ones(sched.n_pairs), None, "q")
    with pytest.raises(AssertionError, match="exactly one"):
        sweep.pair_sweep(emitter, schedule=sched, axis_name="q",
                         mode="scan")

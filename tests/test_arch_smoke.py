"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness (assignment item f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, shape_cells
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh
from repro.models import lm, whisper
from repro.optim import AdamWConfig, adamw_init


def _batch_for(cfg, B=2, T=16):
    rng = np.random.default_rng(0)
    if cfg.frontend == "audio_frames":
        Td = max(1, T // cfg.dec_ratio)
        return {
            "frames": jnp.asarray(rng.normal(size=(B, T, cfg.d_model)),
                                  jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Td))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Td))),
        }
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T))),
    }
    if cfg.frontend == "vision_patches":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vis_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    mod = whisper if cfg.encdec else lm
    params = mod.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = jax.jit(lambda p, b: mod.forward(cfg, p, b))(params, batch)
    B = batch["tokens"].shape[0]
    assert logits.shape[0] == B
    assert logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    mesh = make_mesh((1,), ("data",))
    cfg = steps_mod.prepare_config(cfg, mesh, seq_shard=False)
    step = jax.jit(steps_mod.build_train_step(
        cfg, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=5)))
    mod = whisper if cfg.encdec else lm
    params = mod.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = _batch_for(cfg)
    with mesh:
        params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    B, S = 2, 8
    if cfg.encdec:
        params = whisper.init_params(cfg, jax.random.PRNGKey(0))
        memory = jax.jit(lambda p, f: whisper.encode(cfg, p, f))(
            params, jnp.ones((B, S, cfg.d_model), jnp.float32))
        state = whisper.init_decode_state(cfg, params, B, S, memory)
        step = jax.jit(lambda p, s, t: whisper.decode_step(cfg, p, s, t))
    else:
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        state = lm.init_decode_state(cfg, B, S)
        step = jax.jit(lambda p, s, t: lm.decode_step(cfg, p, s, t))
    toks = jnp.ones((B, 1), jnp.int32)
    logits, state = step(params, state, toks)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert int(state["pos"]) == 1


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (spot-checked per arch)."""
    c = get_config("deepseek_coder_33b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (62, 7168, 56, 8, 19200, 32256)
    c = get_config("qwen3_14b")
    assert c.qk_norm and c.vocab_size == 151936 and c.n_kv_heads == 8
    c = get_config("mamba2_130m")
    assert c.family == "ssm" and c.ssm_state == 128 and c.d_ff == 0
    c = get_config("jamba_v0_1_52b")
    assert c.layer_pattern.count("A") == 1 and len(c.layer_pattern) == 8
    assert c.moe_experts == 16 and c.moe_top_k == 2
    c = get_config("llama4_maverick_400b_a17b")
    assert c.moe_experts == 128 and c.moe_top_k == 1
    c = get_config("qwen2_vl_72b")
    assert c.pos == "mrope" and c.n_layers == 80 and c.d_ff == 29568
    c = get_config("whisper_large_v3")
    assert c.encdec and c.n_enc_layers == 32 and c.vocab_size == 51866
    c = get_config("h2o_danube_1_8b")
    assert c.window == 4096


def test_cell_skips_documented():
    """40 assigned cells; long_500k runs only for ssm/hybrid/SWA families."""
    total = sum(1 for a in ARCHS for _ in shape_cells(a))
    assert total == 10 * 3 + 3  # 30 universal cells + 3 long_500k
    long_archs = {a for a in ARCHS
                  if any(s.name == "long_500k" for s in shape_cells(a))}
    assert long_archs == {"mamba2_130m", "jamba_v0_1_52b", "h2o_danube_1_8b"}

"""Online query subsystem tests (serving/): selfcheck sweeps in fake-device
subprocesses (dry-run isolation rule, see tests/test_distributed.py) plus
single-process unit tests for the merge/selection primitives and the
auto-mode heuristic."""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

SRC = Path(__file__).resolve().parents[1] / "src"


def run_sub(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("P", [4, 5, 8, 12])
def test_serving_selfcheck(P):
    """Acceptance sweep: cover-routed top-k == brute-force oracle (scores
    and indices) in every mode incl. the fused kernel, for both metrics,
    through streamed replace and append updates."""
    out = run_sub(f"from repro.serving.selfcheck import main; main({P})", P)
    assert "serving selfcheck OK" in out
    assert "batched,overlap,scan,kernel" in out


def test_serving_env_mode_override():
    """REPRO_ALLPAIRS_MODE steers the serving auto mode too (shared
    env_mode_override), without changing results."""
    code = """
import os
os.environ["REPRO_ALLPAIRS_MODE"] = "scan"
from repro.serving.selfcheck import main
main(4, modes=("auto",))
"""
    assert "serving selfcheck OK" in run_sub(code, 4)


def test_merge_topk_dedups_and_orders():
    """merge_topk: duplicate indices (tree-merge wraparound) collapse to
    one entry; ties break toward the smaller corpus index."""
    import jax.numpy as jnp

    from repro.kernels.ref import NEG_INF
    from repro.serving.engine import IDX_SENTINEL, merge_topk, topk_by_score

    va = jnp.asarray([[5.0, 3.0, 1.0]])
    ia = jnp.asarray([[7, 2, 9]], dtype=jnp.int32)
    vb = jnp.asarray([[5.0, 3.0, 2.0]])
    ib = jnp.asarray([[7, 4, 11]], dtype=jnp.int32)   # (5.0, 7) duplicated
    v, i = merge_topk(va, ia, vb, ib, 4)
    assert i.tolist() == [[7, 2, 4, 11]]              # tie 3.0: idx 2 < 4
    assert v.tolist() == [[5.0, 3.0, 3.0, 2.0]]

    # short candidate lists pad with sentinels
    v, i = topk_by_score(jnp.asarray([[2.0, 4.0]]),
                         jnp.asarray([[5, 3]], dtype=jnp.int32), 4)
    assert i.tolist() == [[3, 5, int(IDX_SENTINEL), int(IDX_SENTINEL)]]
    assert v[0, 2] == NEG_INF and v[0, 3] == NEG_INF


def test_serving_select_mode_heuristic(monkeypatch):
    """Auto heuristic mirrors the batch engine's: env override wins (and
    conflicts with a fused batch_fn raise), fused kernel forces batched,
    the byte budget pushes big microbatches to overlap/scan."""
    import jax.numpy as jnp

    from repro.core.scheduler import build_schedule
    from repro.serving.engine import _select_mode

    sched = build_schedule(8)   # k = 4
    q = jnp.zeros((16, 8), jnp.float32)

    monkeypatch.delenv("REPRO_ALLPAIRS_MODE", raising=False)
    monkeypatch.delenv("REPRO_BATCH_BYTES_LIMIT", raising=False)
    assert _select_mode(sched, q, 64, None) == "batched"
    assert _select_mode(sched, q, 64, object()) == "batched"

    monkeypatch.setenv("REPRO_ALLPAIRS_MODE", "overlap")
    assert _select_mode(sched, q, 64, None) == "overlap"
    with pytest.raises(ValueError, match="batch_fn"):
        _select_mode(sched, q, 64, object())
    monkeypatch.delenv("REPRO_ALLPAIRS_MODE")

    monkeypatch.setenv("REPRO_BATCH_BYTES_LIMIT", "1")
    assert _select_mode(sched, q, 64, None) == "overlap"   # k >= 3
    sched2 = build_schedule(2)  # k = 2: nothing to parallelize over
    assert _select_mode(sched2, q, 64, None) == "scan"


def test_use_kernel_requires_batched_mode():
    """The fused query kernel only replaces the batched local step."""
    code = """
import numpy as np, jax
from repro.serving import ServingCorpus
from repro.serving.engine import quorum_query_topk
from repro.core.scheduler import build_schedule
import jax.numpy as jnp

mesh = jax.make_mesh((2,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
sc = ServingCorpus.build(np.zeros((8, 4), np.float32), mesh)
try:
    sc.query(np.zeros((2, 4), np.float32), topk=2, mode="scan",
             use_kernel=True)
except ValueError as e:
    assert "use_kernel" in str(e), e
else:
    raise AssertionError("no error for use_kernel + scan")

try:
    quorum_query_topk(jnp.zeros((2, 4)), jnp.zeros((2, 4, 4)),
                      jnp.ones((2, 4), bool), jnp.ones((2,)), topk=2,
                      axis_name="q", schedule=build_schedule(2),
                      mode="overlap", batch_fn=lambda *a: None)
except ValueError as e:
    assert "batch_fn" in str(e), e
else:
    raise AssertionError("no error for engine-level batch_fn conflict")
print("SERVE-KERNEL-GUARD-OK")
"""
    assert "SERVE-KERNEL-GUARD-OK" in run_sub(code, 2)


def test_queries_per_device_work_is_cover_sized():
    """The routing claim itself: only cover devices get non-zero dedup
    mask rows, and the per-device scored-row total equals the valid
    corpus exactly (each row once) — ~N/k of the all-devices baseline per
    cover device."""
    from repro.serving.cover import build_cover

    for P in [4, 8, 12, 31]:
        plan = build_cover(P)
        rows = np.asarray(plan.mask_table())
        active = {i for i in range(P) if rows[i].any()}
        assert active == set(plan.devices)
        assert rows.sum() == P  # one slot-block per corpus block overall

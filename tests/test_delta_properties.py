"""Property tests for the delta-sweep schedule (core/delta.py): random
dirty sets must yield schedules that cover exactly the pairs with a
dirty endpoint, partition ownership exactly once across the holder
quorums, and respect the |D|*P tile bound (DESIGN.md section 16.6).

Skipped wholesale when hypothesis is not installed (same gate as the
other property suites)."""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.delta import delta_rounds, dirty_tiles, owner_partition  # noqa: E402
from repro.core.placement import get_placement  # noqa: E402
from repro.core.sweep import ENGINE_MODES  # noqa: E402


@st.composite
def dirty_case(draw, max_P=16):
    """A (P, dirty set) pair with dirty a (possibly empty) subset of
    range(P)."""
    P = draw(st.integers(min_value=1, max_value=max_P))
    dirty = draw(st.sets(st.integers(min_value=0, max_value=P - 1),
                         max_size=P))
    return P, dirty


@given(dirty_case())
@settings(max_examples=60, deadline=None)
def test_schedule_covers_exactly_dirty_endpoint_pairs(case):
    P, dirty = case
    tiles = dirty_tiles(None, dirty, P=P)
    brute = {(x, y) for x in range(P) for y in range(x, P)
             if x in dirty or y in dirty}
    assert set(tiles) == brute
    assert len(tiles) == len(set(tiles))   # no duplicates
    assert tiles == sorted(tiles)          # deterministic canonical order


@given(dirty_case())
@settings(max_examples=60, deadline=None)
def test_tile_count_formula_and_bound(case):
    P, dirty = case
    tiles = dirty_tiles(None, dirty, P=P)
    d = len(dirty)
    assert len(tiles) == d * P - d * (d - 1) // 2
    assert len(tiles) <= d * P
    full = P * (P + 1) // 2
    if 0 < d < P / 2:
        assert len(tiles) < full   # strictly output-sensitive
    if d == P:
        assert len(tiles) == full  # all-dirty degenerates to a full sweep


@given(st.integers(min_value=1, max_value=16))
@settings(max_examples=40, deadline=None)
def test_ownership_partitions_exactly_once(P):
    plc = get_placement("cyclic", P)
    owners = owner_partition(plc)
    all_tiles = {(x, y) for x in range(P) for y in range(x, P)}
    assert set(owners) == all_tiles          # every tile, exactly once
    assert owners == owner_partition(plc)    # deterministic
    for (x, y), o in owners.items():
        res = plc.residency_sets[o]
        assert x in res and y in res         # the owner co-resides the pair


@given(dirty_case(max_P=13), st.sampled_from(ENGINE_MODES))
@settings(max_examples=60, deadline=None)
def test_rounds_partition_the_schedule(case, mode):
    P, dirty = case
    plc = get_placement("cyclic", P)
    tiles = dirty_tiles(plc, dirty)
    rounds = delta_rounds(plc, tiles, mode)
    flat = [t for grp in rounds for t in grp]
    assert sorted(flat) == sorted(tiles)     # each tile in exactly one round
    assert all(grp for grp in rounds)        # no empty rounds
    if mode == "scan":
        assert all(len(grp) == 1 for grp in rounds)
    if mode == "batched" and tiles:
        assert len(rounds) == 1

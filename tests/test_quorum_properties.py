"""Hypothesis property sweeps for the paper's core math (sections 3-4).

Skipped wholesale when hypothesis is not installed; the deterministic
fixed-P versions in tests/test_quorum.py always run.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quorum import (cyclic_quorums, difference_set,
                               is_difference_cover, ladder_difference_cover,
                               verify_all_pairs_property)


@given(st.integers(min_value=1, max_value=400))
@settings(max_examples=60, deadline=None)
def test_ladder_cover_property(P):
    A = ladder_difference_cover(P)
    assert is_difference_cover(A, P)
    assert len(A) <= 2 * int(np.ceil(np.sqrt(P))) + 2


@given(st.integers(min_value=1, max_value=160))
@settings(max_examples=40, deadline=None)
def test_all_pairs_property(P):
    """Paper Theorem 1: cyclic quorums from a relaxed difference set satisfy
    the all-pairs property (every unordered pair co-resident somewhere)."""
    Q = cyclic_quorums(P)
    assert verify_all_pairs_property(Q, P)


@given(st.integers(min_value=1, max_value=160))
@settings(max_examples=40, deadline=None)
def test_quorum_properties(P):
    """Paper Eq. 10-13: equal size, equal responsibility, intersection."""
    Q = cyclic_quorums(P)
    k = len(Q[0])
    assert all(len(S) == k for S in Q)               # equal work (Eq. 12)
    counts = np.zeros(P, int)
    for S in Q:
        for b in S:
            counts[b] += 1
    assert (counts == k).all()                       # equal responsibility (Eq. 13)
    sets = [set(S) for S in Q]
    if P <= 64:  # O(P^2) check
        for i in range(P):
            for j in range(P):
                assert sets[i] & sets[j]             # intersection (Eq. 10)


@given(st.integers(min_value=1, max_value=300))
@settings(max_examples=50, deadline=None)
def test_memory_scaling(P):
    """The headline claim: one array of k*N/P = O(N/sqrt(P)) elements."""
    A = difference_set(P)
    assert len(A) <= max(3, 2.1 * np.sqrt(P) + 2)

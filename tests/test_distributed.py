"""Distributed engine tests — run in subprocesses with fake devices so the
main pytest process keeps a single CPU device (dry-run isolation rule)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = Path(__file__).resolve().parents[1] / "src"


def run_sub(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("P", [4, 5, 8])
def test_quorum_allpairs_engine(P):
    out = run_sub(f"from repro.core.selfcheck import main; main({P})", P)
    assert "selfcheck OK" in out


def test_pcit_distributed_matches_reference():
    code = """
import numpy as np, jax
from repro.apps.pcit import run_quorum_pcit, pcit_reference, correlation_reference
rng = np.random.default_rng(0)
N, G = 32, 20
Z = rng.normal(size=(4, G)); W = rng.normal(size=(N, 4))
X = W @ Z + 0.5 * rng.normal(size=(N, G))
mesh = jax.make_mesh((8,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
corr, keep = run_quorum_pcit(X, mesh)
np.testing.assert_allclose(corr, correlation_reference(X), rtol=1e-4, atol=1e-5)
assert (keep == pcit_reference(X)).all()
print("PCIT-OK")
"""
    assert "PCIT-OK" in run_sub(code, 8)


@pytest.mark.parametrize("strategy", ["quorum", "ring"])
def test_distributed_attention(strategy):
    code = f"""
import numpy as np, jax, jax.numpy as jnp
from repro.apps.attention import distributed_attention, reference_attention
rng = np.random.default_rng(0)
B, T, H, KV, hd = 2, 64, 4, 2, 16
q = jnp.asarray(rng.normal(size=(B,T,H,hd)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B,T,KV,hd)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B,T,KV,hd)), jnp.float32)
mesh = jax.make_mesh((8,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
out = distributed_attention(q, k, v, mesh, strategy="{strategy}")
err = np.abs(np.asarray(out) - np.asarray(reference_attention(q, k, v))).max()
assert err < 1e-4, err
print("ATTN-OK", err)
"""
    assert "ATTN-OK" in run_sub(code, 8)


def test_nbody_strategies_agree():
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.apps.nbody import distributed_forces, forces_reference
rng = np.random.default_rng(1)
N = 64
bodies = np.concatenate([rng.normal(size=(N,3)),
                         rng.uniform(0.5, 2, (N,1))], -1).astype(np.float32)
mesh = jax.make_mesh((8,), ("q",), axis_types=(jax.sharding.AxisType.Auto,))
ref = forces_reference(bodies)
for strat in ["quorum", "atom"]:
    out = np.asarray(distributed_forces(jnp.asarray(bodies), mesh, strategy=strat))
    assert np.abs(out - ref).max() / np.abs(ref).max() < 1e-4
print("NBODY-OK")
"""
    assert "NBODY-OK" in run_sub(code, 8)


def test_quorum_memory_footprint():
    """The paper's claim, measured: per-device resident quorum bytes are
    k/P of the all-gather baseline's."""
    code = """
import numpy as np
from repro.core.scheduler import build_schedule
for P in [8, 16, 64]:
    s = build_schedule(P)
    N = 1024 * P
    quorum_elems = s.k * (N // P)
    allgather_elems = N
    ratio = quorum_elems / allgather_elems
    assert abs(ratio - s.k / P) < 1e-9
    assert ratio <= 2.2 / np.sqrt(P) + 0.2
print("MEM-OK")
"""
    assert "MEM-OK" in run_sub(code, 1)

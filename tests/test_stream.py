"""Streaming-update layer tests (serving/stream.py): direct coverage of
the block-update path — append-then-replace ordering on one block,
propagation to overlapping holder quorums, ragged (short) appends and
the validity column, and the dirty-block listener hooks that feed
standing delta indexes (DESIGN.md sections 12 and 16.5).

Device-touching tests run in fake-device subprocesses (the dry-run
isolation rule, see tests/test_distributed.py); the listener registry is
pure host code and is exercised in-process as well."""

import os
import subprocess
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"


def run_sub(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_append_then_replace_ordering_same_block():
    """An append (a replace into empty capacity) followed by a replace of
    the same block must leave only the second write visible: rows beyond
    the new data are zeroed and invalid — no stale append rows linger."""
    code = """
import jax, numpy as np
from repro.serving.stream import build_state, replace_block
mesh = jax.make_mesh((4,), ("q",))
rng = np.random.default_rng(0)
corpus = rng.normal(size=(8, 3)).astype(np.float32)
st = build_state(corpus, mesh, block=4)        # capacity 16; block 3 hosts
a = rng.normal(size=(3, 3)).astype(np.float32)  # rows 12..14
st = replace_block(st, mesh, "q", 3, a)         # the 'append'
assert np.asarray(st.valid)[12:16].tolist() == [True, True, True, False]
b = rng.normal(size=(1, 3)).astype(np.float32)
st = replace_block(st, mesh, "q", 3, b)         # then replace, shorter
shard = np.asarray(st.shard); valid = np.asarray(st.valid)
np.testing.assert_array_equal(shard[12], b[0])
assert valid[12:16].tolist() == [True, False, False, False]
np.testing.assert_array_equal(shard[13:16], np.zeros((3, 3), np.float32))
print("ORDERING-OK")
"""
    assert "ORDERING-OK" in run_sub(code, 4)


def test_replace_propagates_to_overlapping_quorums():
    """A replaced block must land at every holder's matching stack slot
    (the block lives in k overlapping quorums) and leave every other
    slot — and its validity row — bit-untouched."""
    code = """
import jax, numpy as np
from repro.core.placement import get_placement
from repro.serving.stream import build_state, replace_block
P = 5
plc = get_placement("cyclic", P)
mesh = jax.make_mesh((P,), ("q",))
rng = np.random.default_rng(1)
corpus = rng.normal(size=(P * 2, 3)).astype(np.float32)
st = build_state(corpus, mesh, placement=plc)
A = plc.schedule().A
k = len(A)
assert len(plc.block_holders(2)) == k >= 2
new = rng.normal(size=(2, 3)).astype(np.float32)
st2 = replace_block(st, mesh, "q", 2, new, placement=plc)
s0, s1 = np.asarray(st.stack), np.asarray(st2.stack)
v0, v1 = np.asarray(st.stack_valid), np.asarray(st2.stack_valid)
touched = 0
for i in range(P):
    for s, a in enumerate(A):
        r = i * k + s
        if (i + a) % P == 2:       # device i's slot s holds block 2
            touched += 1
            np.testing.assert_array_equal(s1[r], new)
            assert v1[r].all()
        else:                      # every other slot arrives unchanged
            np.testing.assert_array_equal(s1[r], s0[r])
            np.testing.assert_array_equal(v1[r], v0[r])
assert touched == k
print("QUORUM-OK")
"""
    assert "QUORUM-OK" in run_sub(code, 5)


def test_ragged_append_validity_column():
    """A short (ragged) append: nvalid < data rows marks the tail
    invalid in both the owner shard and every holder's stack-validity
    row (the validity column rides the same permute as the data), and
    out-of-range nvalid is rejected."""
    code = """
import jax, numpy as np
from repro.core.placement import placement_from_env
from repro.serving.stream import build_state, replace_block
P = 4
mesh = jax.make_mesh((P,), ("q",))
rng = np.random.default_rng(2)
corpus = rng.normal(size=(6, 3)).astype(np.float32)
st = build_state(corpus, mesh, block=2)         # block 3 (rows 6,7) empty
assert np.asarray(st.valid).sum() == 6
data = rng.normal(size=(2, 3)).astype(np.float32)
st2 = replace_block(st, mesh, "q", 3, data, nvalid=1)
valid = np.asarray(st2.valid); shard = np.asarray(st2.shard)
assert valid[6] and not valid[7]
np.testing.assert_array_equal(shard[6:8], data)  # data lands, row 7 invalid
plc = placement_from_env(P)
A = plc.schedule().A
k = len(A)
sv = np.asarray(st2.stack_valid); stk = np.asarray(st2.stack)
seen = 0
for i in range(P):
    for s, a in enumerate(A):
        if (i + a) % P == 3:
            r = i * k + s
            seen += 1
            assert sv[r, 0] and not sv[r, 1]
            np.testing.assert_array_equal(stk[r], data)
assert seen == k
try:
    replace_block(st, mesh, "q", 3, data, nvalid=3)
    raise SystemExit("nvalid=3 > rows must raise")
except ValueError:
    pass
try:
    replace_block(st, mesh, "q", 3, rng.normal(size=(3, 3)).astype(np.float32))
    raise SystemExit("rows > block capacity must raise")
except ValueError:
    pass
print("RAGGED-OK")
"""
    assert "RAGGED-OK" in run_sub(code, 4)


def test_dirty_listener_fires_per_update():
    """Every streamed update (replace, and append via the serving corpus)
    notifies registered dirty listeners with the block id — the hook
    that marks core.delta.DeltaIndex standing outputs dirty."""
    code = """
import jax, numpy as np
from repro.serving.stream import (build_state, register_dirty_listener,
                                  replace_block, unregister_dirty_listener)
mesh = jax.make_mesh((4,), ("q",))
rng = np.random.default_rng(3)
corpus = rng.normal(size=(8, 3)).astype(np.float32)
st = build_state(corpus, mesh)
seen = []
hook = register_dirty_listener(seen.append)   # returns fn (decorator form)
assert hook is seen.append or hook == seen.append
st = replace_block(st, mesh, "q", 1, rng.normal(size=(2, 3)).astype(np.float32))
st = replace_block(st, mesh, "q", 3, rng.normal(size=(2, 3)).astype(np.float32))
assert seen == [1, 3], seen
unregister_dirty_listener(seen.append)
st = replace_block(st, mesh, "q", 0, rng.normal(size=(2, 3)).astype(np.float32))
assert seen == [1, 3], seen                   # unregistered: no more events
unregister_dirty_listener(seen.append)        # double-remove is a no-op
print("LISTENER-OK")
"""
    assert "LISTENER-OK" in run_sub(code, 4)


def test_listener_registry_is_host_only():
    """The registry itself needs no devices: register/unregister and the
    decorator form work without touching jax."""
    from repro.serving import stream

    seen = []

    @stream.register_dirty_listener
    def hook(b):
        seen.append(b)

    try:
        stream._notify_dirty(7)
        assert seen == [7]
    finally:
        stream.unregister_dirty_listener(hook)
    stream._notify_dirty(9)
    assert seen == [7]
    stream.unregister_dirty_listener(hook)  # no-op after removal

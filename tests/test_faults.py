"""Fault-tolerant sweep execution (core/faults.py): fault plans, the
round-based recovery driver, re-replication, checkpoint restore on block
loss, and the chaos selfcheck contract (DESIGN.md section 13)."""

import numpy as np
import pytest

from repro.core.faults import (CHAOS_P, DenseReduceWorkload, FaultEvent,
                               FaultPlan, KnnGraphWorkload,
                               SparseJoinWorkload, WORKLOADS,
                               chaos_selfcheck, residency_invariant_ok,
                               run_fault_tolerant_sweep)
from repro.core.placement import get_placement
from repro.core.sweep import ENGINE_MODES, sweep_rounds


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def test_fault_event_validates_kind():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent("explode", 0, 1)


def test_fault_plan_is_deterministic():
    a = FaultPlan.random_kills(8, 6, every=2, seed=3)
    b = FaultPlan.random_kills(8, 6, every=2, seed=3)
    assert a == b
    c = FaultPlan.random_kills(8, 6, every=2, seed=4)
    assert a != c


def test_fault_plan_never_kills_last_survivor():
    plan = FaultPlan.random_kills(3, 50, every=1, seed=0, chaos=False)
    assert plan.n_kills == 2  # P - 1 kills max


def test_fault_plan_short_sweep_still_kills():
    """batched mode has one round; the plan must not degenerate to
    fault-free just because every > n_rounds."""
    plan = FaultPlan.random_kills(8, 1, every=4, seed=0)
    assert plan.n_kills == 1
    assert plan.events_at(0)[0].kind == "kill"


def test_events_at_orders_kills_first():
    plan = FaultPlan(events=(
        FaultEvent("slow", 1, 2, factor=2.0),
        FaultEvent("kill", 1, 0), FaultEvent("drop", 1)))
    kinds = [e.kind for e in plan.events_at(1)]
    assert kinds == ["kill", "drop", "slow"]
    assert plan.events_at(0) == []


# ---------------------------------------------------------------------------
# sweep_rounds — the synchronization boundary structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [1, 2, 5, 8, 13])
def test_sweep_rounds_partition_pairs(P):
    sched = get_placement("cyclic", P).schedule()
    for mode in ENGINE_MODES:
        rounds = sweep_rounds(sched, mode)
        flat = [s for grp in rounds for s in grp]
        assert sorted(flat) == list(range(sched.n_pairs)), (P, mode)
        assert all(grp for grp in rounds)
    assert len(sweep_rounds(sched, "batched")) == 1
    assert len(sweep_rounds(sched, "scan")) == sched.n_pairs


def test_sweep_rounds_rejects_bad_mode():
    sched = get_placement("cyclic", 4).schedule()
    with pytest.raises(ValueError, match="mode"):
        sweep_rounds(sched, "auto")


# ---------------------------------------------------------------------------
# driver: fault-free runs agree across modes and match the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wl_cls", WORKLOADS, ids=lambda c: c.name)
def test_fault_free_modes_bit_identical(wl_cls):
    P = 8
    plc = get_placement("cyclic", P)
    wl = wl_cls(P, seed=1)
    results = []
    for mode in ENGINE_MODES:
        out, stats = run_fault_tolerant_sweep(wl, plc, mode)
        assert stats.n_kills == stats.n_fetches == 0
        assert stats.rounds == len(sweep_rounds(plc.schedule(), mode))
        results.append(out)
    wl.check_oracle(results[0])
    for out in results[1:]:
        assert wl.equal(out, results[0])


# ---------------------------------------------------------------------------
# driver: chaos (kills + drops + slowdowns) stays bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wl_cls", WORKLOADS, ids=lambda c: c.name)
@pytest.mark.parametrize("mode", ENGINE_MODES)
def test_chaos_run_bit_exact(wl_cls, mode, tmp_path):
    P = 8
    plc = get_placement("cyclic", P)
    wl = wl_cls(P, seed=2)
    baseline, _ = run_fault_tolerant_sweep(wl, plc, "batched")
    n_rounds = len(sweep_rounds(plc.schedule(), mode))
    plan = FaultPlan.random_kills(P, n_rounds, every=1, seed=5)
    out, stats = run_fault_tolerant_sweep(
        wl, plc, mode, plan, ckpt_dir=str(tmp_path / "ckpt"))
    assert stats.n_kills == plan.n_kills > 0
    assert stats.n_reassigned > 0
    assert wl.equal(out, baseline)


def test_recovery_restores_residency_invariant():
    """After each repair the driver asserts the k-residency invariant;
    drive it through a multi-kill plan and cross-check the predicate
    directly on a hand-built state."""
    P = 13
    plc = get_placement("projective", P)
    wl = DenseReduceWorkload(P, seed=0)
    plan = FaultPlan.random_kills(
        P, len(sweep_rounds(plc.schedule(), "scan")), every=2, seed=1)
    baseline, _ = run_fault_tolerant_sweep(wl, plc, "batched")
    out, stats = run_fault_tolerant_sweep(wl, plc, "scan", plan)
    assert stats.n_rereplicated > 0
    assert wl.equal(out, baseline)
    # the predicate itself
    res = [set(S) for S in plc.residency_sets]
    alive = [True] * P
    assert residency_invariant_ok(plc, res, alive)
    alive[0] = False
    res[0] = set()
    assert not residency_invariant_ok(plc, res, alive)


# ---------------------------------------------------------------------------
# block loss end-to-end: reassign refuses, checkpoint restore resumes
# ---------------------------------------------------------------------------

def _holders_of_block(plc, b):
    return [i for i in range(plc.P) if b in plc.residency_sets[i]]


@pytest.mark.parametrize("wl_cls", WORKLOADS, ids=lambda c: c.name)
def test_block_loss_restores_from_checkpoint(wl_cls, tmp_path):
    """All k holders of block 0 die mid-sweep: reassign raises "block
    lost", the driver restores blocks + durable partials from the
    ckpt/checkpoint.py store, re-runs the tail, and the final output is
    still bit-exact — the RuntimeError's promised recovery path,
    exercised end-to-end."""
    P = 8
    plc = get_placement("cyclic", P)
    holders = _holders_of_block(plc, 0)
    assert len(holders) < P
    wl = wl_cls(P, seed=3)
    baseline, _ = run_fault_tolerant_sweep(wl, plc, "batched")
    n_rounds = len(sweep_rounds(plc.schedule(), "scan"))
    assert n_rounds >= 3
    kill_round = 2  # after two checkpointed rounds
    plan = FaultPlan(events=tuple(
        FaultEvent("kill", kill_round, d) for d in holders))
    out, stats = run_fault_tolerant_sweep(
        wl, plc, "scan", plan, ckpt_dir=str(tmp_path / "ckpt"),
        ckpt_every=1)
    assert stats.n_kills == len(holders)
    assert stats.n_restores >= 1
    assert wl.equal(out, baseline)


def test_block_loss_without_checkpoint_reseeds_pristine():
    """No checkpoint directory: the restore path falls back to the
    pristine input blocks (stable storage) and recomputes everything —
    still no wrong answer."""
    P = 8
    plc = get_placement("cyclic", P)
    holders = _holders_of_block(plc, 0)
    wl = DenseReduceWorkload(P, seed=4)
    baseline, _ = run_fault_tolerant_sweep(wl, plc, "batched")
    plan = FaultPlan(events=tuple(
        FaultEvent("kill", 1, d) for d in holders))
    out, stats = run_fault_tolerant_sweep(wl, plc, "scan", plan)
    assert stats.n_restores >= 1
    assert wl.equal(out, baseline)


def test_checkpoint_store_roundtrips_partials(tmp_path):
    """The mid-sweep store really is ckpt/checkpoint.py: manifests
    appear per round boundary, and the named-tree loader recovers
    decodable partials."""
    from repro.ckpt.checkpoint import latest_step, restore_or_none

    P = 5
    plc = get_placement("cyclic", P)
    wl = SparseJoinWorkload(P, seed=0)
    d = str(tmp_path / "ckpt")
    assert restore_or_none(d) is None
    out, stats = run_fault_tolerant_sweep(
        wl, plc, "scan", ckpt_dir=d, ckpt_every=1)
    n_rounds = len(sweep_rounds(plc.schedule(), "scan"))
    assert stats.n_checkpoints == n_rounds
    assert latest_step(d) == n_rounds
    tree, step = restore_or_none(d)
    assert step == n_rounds
    assert int(tree["round"]) == n_rounds
    assert set(tree["blocks"]) == {str(b) for b in range(P)}
    partials = {tuple(int(v) for v in k.split("_")): wl.decode_partial(v)
                for k, v in tree["partials"].items()}
    assert len(partials) == P * (P + 1) // 2
    assert wl.equal(wl.fold(partials), out)


def test_ckpt_every_knob_controls_cadence(tmp_path, monkeypatch):
    P = 5
    plc = get_placement("cyclic", P)
    wl = DenseReduceWorkload(P, seed=0)
    monkeypatch.setenv("REPRO_CKPT_EVERY", "2")
    _out, stats = run_fault_tolerant_sweep(
        wl, plc, "scan", ckpt_dir=str(tmp_path / "ckpt"))
    n_rounds = len(sweep_rounds(plc.schedule(), "scan"))
    assert stats.n_checkpoints == n_rounds // 2
    monkeypatch.setenv("REPRO_CKPT_EVERY", "zero")
    with pytest.raises(ValueError, match="REPRO_CKPT_EVERY"):
        run_fault_tolerant_sweep(wl, plc, "scan",
                                 ckpt_dir=str(tmp_path / "c2"))


# ---------------------------------------------------------------------------
# weighted ownership rides the same driver
# ---------------------------------------------------------------------------

def test_weighted_ownership_same_result_more_fetches():
    """Non-uniform capacity weights change who computes, not what:
    the result stays bit-identical; single-block owners pull their
    missing block over the tier-2 fetch path."""
    P = 8
    plc = get_placement("cyclic", P)
    wl = DenseReduceWorkload(P, seed=5)
    baseline, base_stats = run_fault_tolerant_sweep(wl, plc, "batched")
    assert base_stats.n_fetches == 0
    weights = [4.0 if i == 0 else 1.0 for i in range(P)]
    out, stats = run_fault_tolerant_sweep(
        wl, plc, "batched", weights=weights)
    assert wl.equal(out, baseline)
    assert stats.n_fetches > 0  # weighted owners hold >= 1 block, not 2


def test_weighted_ownership_survives_faults(tmp_path):
    P = 12
    plc = get_placement("affine", P)
    wl = KnnGraphWorkload(P, seed=6)
    weights = [1.0 + (i % 3) for i in range(P)]
    baseline, _ = run_fault_tolerant_sweep(wl, plc, "batched")
    plan = FaultPlan.random_kills(
        P, len(sweep_rounds(plc.schedule(), "overlap")), every=2, seed=2)
    out, stats = run_fault_tolerant_sweep(
        wl, plc, "overlap", plan, ckpt_dir=str(tmp_path / "ckpt"),
        weights=weights)
    assert stats.n_kills > 0
    assert wl.equal(out, baseline)


# ---------------------------------------------------------------------------
# the chaos selfcheck entry point (a small slice; CI runs the matrix)
# ---------------------------------------------------------------------------

def test_chaos_selfcheck_small_slice():
    n = chaos_selfcheck(Ps=(5,), modes=("scan",),
                        placements=("cyclic",), verbose=False)
    assert n == 3  # three workloads x one placement x one mode


def test_chaos_constants_match_issue_acceptance():
    assert CHAOS_P == (5, 7, 8, 12, 13)

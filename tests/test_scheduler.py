"""Schedule coverage, balance, ownership, and fault-tolerance tests.

Hypothesis property sweeps live in tests/test_scheduler_properties.py
(skipped without hypothesis); everything here is deterministic.
"""

import numpy as np
import pytest

from repro.core.scheduler import (FETCH_LOAD_WEIGHT, build_causal_schedule,
                                  build_schedule, reassign)


@pytest.mark.parametrize("P", [1, 2, 3, 4, 5, 6, 8, 12, 31, 96])
def test_full_schedule_exact_coverage(P):
    """Every unordered pair computed exactly once (d = P/2 orbit twice,
    deduplicated by the engine mask)."""
    s = build_schedule(P)
    count = np.zeros((P, P), int)
    for i in range(P):
        for (x, y) in s.global_pairs_of(i):
            a, b = min(x, y), max(x, y)
            count[a, b] += 1
    for a in range(P):
        for b in range(a, P):
            d = (b - a) % P
            dd = min(d, P - d)
            expected = 2 if (P % 2 == 0 and P > 1 and dd == P // 2) else 1
            assert count[a, b] == expected, (P, a, b)


@pytest.mark.parametrize("P", [1, 2, 5, 7, 16, 48, 96])
def test_perfect_static_balance(P):
    """Every device owns exactly one pair per difference — identical op
    sequence lengths (straggler-free by construction)."""
    s = build_schedule(P)
    assert s.n_pairs == P // 2 + 1
    # all devices share the same slot-index pair list by construction
    for i in range(P):
        assert len(s.global_pairs_of(i)) == s.n_pairs


@pytest.mark.parametrize("P", list(range(1, 13)))
def test_owner_of_matches_global_pairs(P):
    """Exhaustive cross-check (all P <= 12, all unordered pairs): owner_of
    agrees with the pair lists global_pairs_of enumerates — the owner it
    names does compute the pair, and away from the doubly-owned d = P/2
    orbit it is the unique such device."""
    s = build_schedule(P)
    owners = {}  # normalized pair -> set of devices that compute it
    for i in range(P):
        for (x, y) in s.global_pairs_of(i):
            owners.setdefault((min(x, y), max(x, y)), set()).add(i)
    for x in range(P):
        for y in range(x, P):
            key = (x, y)
            want = owners[key]
            d = (y - x) % P
            dd = min(d, P - d) if P > 1 else 0
            double = P % 2 == 0 and P > 1 and dd == P // 2
            assert len(want) == (2 if double else 1), (P, key, want)
            # owner_of must name a device that actually computes the pair,
            # under both argument orders
            assert s.owner_of(x, y) in want, (P, key)
            assert s.owner_of(y, x) in want, (P, key)
            if not double:
                assert s.owner_of(x, y) == s.owner_of(y, x)


@pytest.mark.parametrize("P", [1, 2, 4, 9, 16, 33, 64])
def test_causal_schedule_coverage(P):
    cs = build_causal_schedule(P)
    cover = np.zeros((P, P), int)
    for i in range(P):
        for sidx in range(cs.n_pairs):
            if cs.valid[i, sidx]:
                kv = (i + int(cs.shifts[cs.pair_slots[sidx, 0]])) % P
                q = (i + int(cs.shifts[cs.pair_slots[sidx, 1]])) % P
                cover[q, kv] += 1
    want = np.tril(np.ones((P, P), int))
    np.testing.assert_array_equal(cover, want)


@pytest.mark.parametrize("P,failed", [(8, [2]), (16, [3]), (16, [3, 7]),
                                      (16, [0, 5, 10]), (32, [31])])
def test_reassign_recovers_all_pairs(P, failed):
    s = build_schedule(P)
    plan = reassign(s, failed)
    assert plan.n_recovered == len(failed) * s.n_pairs
    # recovered work lands only on live devices
    for i in list(plan.extra_pairs) + list(plan.fetch_pairs):
        assert i not in failed


def test_reassign_block_loss_detected():
    """If all k holders of a block fail, reassignment must refuse (data loss
    -> checkpoint restore is the correct response)."""
    P = 8
    s = build_schedule(P)
    from repro.core.quorum import cyclic_quorums
    holders = [i for i, S in enumerate(cyclic_quorums(P)) if 0 in S]
    with pytest.raises(RuntimeError, match="lost"):
        reassign(s, holders)


@pytest.mark.parametrize("P,failed", [(8, [2]), (16, [3, 7]), (13, [0])])
def test_reassign_load_accounting(P, failed):
    """Regression for the n_recovered / load-model inconsistency: tier-2
    fetches cost FETCH_LOAD_WEIGHT in the greedy's load model but count
    1 in n_recovered; the plan must expose both quantities and they must
    reconcile exactly."""
    s = build_schedule(P)
    plan = reassign(s, failed)
    n_tier1 = sum(len(v) for v in plan.extra_pairs.values())
    n_tier2 = sum(len(v) for v in plan.fetch_pairs.values())
    assert plan.n_recovered == n_tier1 + n_tier2
    assert plan.weighted_load == n_tier1 + FETCH_LOAD_WEIGHT * n_tier2
    if n_tier2:
        assert plan.weighted_load > plan.n_recovered
    # fetched_blocks mirrors fetch_pairs in deterministic order
    fetched = plan.fetched_blocks
    assert len(fetched) == n_tier2
    for (blk, src, tgt) in fetched:
        assert src not in failed and tgt not in failed


@pytest.mark.parametrize("P,failed", [(8, [2]), (16, [3, 7]), (32, [31]),
                                      (13, [0, 6, 11])])
def test_reassign_plan_is_stable(P, failed):
    """The greedy tie-break is deterministic (sorted candidates, ties to
    the smallest id): the same inputs always produce the identical plan,
    in any failed-device order — mid-sweep recovery replays depend on
    this."""
    s = build_schedule(P)
    a = reassign(s, failed)
    b = reassign(s, list(reversed(failed)))
    assert a == b
    assert a == reassign(s, failed)


def test_reassign_pairs_override_restricts_todo():
    """The fault-tolerant driver hands reassign only the *remaining*
    tiles of a dead device; the plan must recover exactly those."""
    P = 16
    s = build_schedule(P)
    remaining = s.global_pairs_of(3)[:2]
    plan = reassign(s, [3], pairs={3: remaining})
    assert plan.n_recovered == 2
    recovered = [p for v in plan.extra_pairs.values() for p in v]
    recovered += [pair for v in plan.fetch_pairs.values()
                  for (pair, _m, _s) in v]
    want = sorted((min(x, y), max(x, y)) for (x, y) in remaining)
    assert sorted(recovered) == want
    # empty override: nothing to recover
    empty = reassign(s, [3], pairs={3: []})
    assert empty.n_recovered == 0 and empty.weighted_load == 0.0


def test_reassign_weights_steer_absorption():
    """Capacity weights (Rocket heterogeneity): a high-capacity survivor
    absorbs more of the recovered load than a low-capacity one, and
    uniform weights reproduce the unweighted plan bit-identically."""
    P = 16
    s = build_schedule(P)
    base = reassign(s, [5])
    assert reassign(s, [5], weights=[1.0] * P) == base
    heavy = 0 if 5 != 0 else 1
    weights = [8.0 if i == heavy else 1.0 for i in range(P)]
    plan = reassign(s, [5], weights=weights)

    def absorbed(pl, i):
        return (len(pl.extra_pairs.get(i, []))
                + len(pl.fetch_pairs.get(i, [])))

    others = [i for i in range(P) if i not in (5, heavy)]
    assert absorbed(plan, heavy) >= max(absorbed(plan, i) for i in others)
    assert absorbed(plan, heavy) > absorbed(base, heavy)
    with pytest.raises(ValueError, match="weights"):
        reassign(s, [5], weights=[1.0] * (P - 1))
    with pytest.raises(ValueError, match="positive"):
        reassign(s, [5], weights=[0.0] + [1.0] * (P - 1))

"""Schedule coverage, balance, ownership, and fault-tolerance tests.

Hypothesis property sweeps live in tests/test_scheduler_properties.py
(skipped without hypothesis); everything here is deterministic.
"""

import numpy as np
import pytest

from repro.core.scheduler import (build_causal_schedule, build_schedule,
                                  reassign)


@pytest.mark.parametrize("P", [1, 2, 3, 4, 5, 6, 8, 12, 31, 96])
def test_full_schedule_exact_coverage(P):
    """Every unordered pair computed exactly once (d = P/2 orbit twice,
    deduplicated by the engine mask)."""
    s = build_schedule(P)
    count = np.zeros((P, P), int)
    for i in range(P):
        for (x, y) in s.global_pairs_of(i):
            a, b = min(x, y), max(x, y)
            count[a, b] += 1
    for a in range(P):
        for b in range(a, P):
            d = (b - a) % P
            dd = min(d, P - d)
            expected = 2 if (P % 2 == 0 and P > 1 and dd == P // 2) else 1
            assert count[a, b] == expected, (P, a, b)


@pytest.mark.parametrize("P", [1, 2, 5, 7, 16, 48, 96])
def test_perfect_static_balance(P):
    """Every device owns exactly one pair per difference — identical op
    sequence lengths (straggler-free by construction)."""
    s = build_schedule(P)
    assert s.n_pairs == P // 2 + 1
    # all devices share the same slot-index pair list by construction
    for i in range(P):
        assert len(s.global_pairs_of(i)) == s.n_pairs


@pytest.mark.parametrize("P", list(range(1, 13)))
def test_owner_of_matches_global_pairs(P):
    """Exhaustive cross-check (all P <= 12, all unordered pairs): owner_of
    agrees with the pair lists global_pairs_of enumerates — the owner it
    names does compute the pair, and away from the doubly-owned d = P/2
    orbit it is the unique such device."""
    s = build_schedule(P)
    owners = {}  # normalized pair -> set of devices that compute it
    for i in range(P):
        for (x, y) in s.global_pairs_of(i):
            owners.setdefault((min(x, y), max(x, y)), set()).add(i)
    for x in range(P):
        for y in range(x, P):
            key = (x, y)
            want = owners[key]
            d = (y - x) % P
            dd = min(d, P - d) if P > 1 else 0
            double = P % 2 == 0 and P > 1 and dd == P // 2
            assert len(want) == (2 if double else 1), (P, key, want)
            # owner_of must name a device that actually computes the pair,
            # under both argument orders
            assert s.owner_of(x, y) in want, (P, key)
            assert s.owner_of(y, x) in want, (P, key)
            if not double:
                assert s.owner_of(x, y) == s.owner_of(y, x)


@pytest.mark.parametrize("P", [1, 2, 4, 9, 16, 33, 64])
def test_causal_schedule_coverage(P):
    cs = build_causal_schedule(P)
    cover = np.zeros((P, P), int)
    for i in range(P):
        for sidx in range(cs.n_pairs):
            if cs.valid[i, sidx]:
                kv = (i + int(cs.shifts[cs.pair_slots[sidx, 0]])) % P
                q = (i + int(cs.shifts[cs.pair_slots[sidx, 1]])) % P
                cover[q, kv] += 1
    want = np.tril(np.ones((P, P), int))
    np.testing.assert_array_equal(cover, want)


@pytest.mark.parametrize("P,failed", [(8, [2]), (16, [3]), (16, [3, 7]),
                                      (16, [0, 5, 10]), (32, [31])])
def test_reassign_recovers_all_pairs(P, failed):
    s = build_schedule(P)
    plan = reassign(s, failed)
    assert plan.n_recovered == len(failed) * s.n_pairs
    # recovered work lands only on live devices
    for i in list(plan.extra_pairs) + list(plan.fetch_pairs):
        assert i not in failed


def test_reassign_block_loss_detected():
    """If all k holders of a block fail, reassignment must refuse (data loss
    -> checkpoint restore is the correct response)."""
    P = 8
    s = build_schedule(P)
    from repro.core.quorum import cyclic_quorums
    holders = [i for i, S in enumerate(cyclic_quorums(P)) if 0 in S]
    with pytest.raises(RuntimeError, match="lost"):
        reassign(s, holders)

"""Schedule coverage, balance, and fault-tolerance reassignment tests."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.scheduler import (build_causal_schedule, build_schedule,
                                  reassign)


@given(st.integers(min_value=1, max_value=96))
@settings(max_examples=40, deadline=None)
def test_full_schedule_exact_coverage(P):
    """Every unordered pair computed exactly once (d = P/2 orbit twice,
    deduplicated by the engine mask)."""
    s = build_schedule(P)
    count = np.zeros((P, P), int)
    for i in range(P):
        for (x, y) in s.global_pairs_of(i):
            a, b = min(x, y), max(x, y)
            count[a, b] += 1
    for a in range(P):
        for b in range(a, P):
            d = (b - a) % P
            dd = min(d, P - d)
            expected = 2 if (P % 2 == 0 and P > 1 and dd == P // 2) else 1
            assert count[a, b] == expected, (P, a, b)


@given(st.integers(min_value=1, max_value=96))
@settings(max_examples=40, deadline=None)
def test_perfect_static_balance(P):
    """Every device owns exactly one pair per difference — identical op
    sequence lengths (straggler-free by construction)."""
    s = build_schedule(P)
    assert s.n_pairs == P // 2 + 1
    # all devices share the same slot-index pair list by construction
    for i in range(P):
        assert len(s.global_pairs_of(i)) == s.n_pairs


@given(st.integers(min_value=1, max_value=64))
@settings(max_examples=30, deadline=None)
def test_causal_schedule_coverage(P):
    cs = build_causal_schedule(P)
    cover = np.zeros((P, P), int)
    for i in range(P):
        for sidx in range(cs.n_pairs):
            if cs.valid[i, sidx]:
                kv = (i + int(cs.shifts[cs.pair_slots[sidx, 0]])) % P
                q = (i + int(cs.shifts[cs.pair_slots[sidx, 1]])) % P
                cover[q, kv] += 1
    want = np.tril(np.ones((P, P), int))
    np.testing.assert_array_equal(cover, want)


@pytest.mark.parametrize("P,failed", [(8, [2]), (16, [3]), (16, [3, 7]),
                                      (16, [0, 5, 10]), (32, [31])])
def test_reassign_recovers_all_pairs(P, failed):
    s = build_schedule(P)
    plan = reassign(s, failed)
    assert plan.n_recovered == len(failed) * s.n_pairs
    # recovered work lands only on live devices
    for i in list(plan.extra_pairs) + list(plan.fetch_pairs):
        assert i not in failed


def test_reassign_block_loss_detected():
    """If all k holders of a block fail, reassignment must refuse (data loss
    -> checkpoint restore is the correct response)."""
    P = 8
    s = build_schedule(P)
    from repro.core.quorum import cyclic_quorums
    holders = [i for i, S in enumerate(cyclic_quorums(P)) if 0 in S]
    with pytest.raises(RuntimeError, match="lost"):
        reassign(s, holders)

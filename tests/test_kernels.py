"""Per-kernel allclose sweeps: Pallas (interpret mode on CPU) vs ref.py."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("M,N,G", [(128, 128, 128), (64, 96, 50),
                                   (256, 128, 384), (32, 32, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_corr(M, N, G, dtype):
    xi = jnp.asarray(RNG.normal(size=(M, G)), dtype)
    xj = jnp.asarray(RNG.normal(size=(N, G)), dtype)
    out = ops.pairwise_corr(xi, xj)
    want = ref.pairwise_corr(xi.astype(jnp.float32), xj.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("M,N,Z,bm", [(32, 32, 64, 16), (16, 48, 96, 16),
                                      (64, 64, 128, 32)])
def test_pcit_filter(M, N, Z, bm):
    rows = RNG.normal(size=(Z, 24))
    rows = rows / np.linalg.norm(rows, axis=1, keepdims=True)
    R = rows @ rows.T
    gx = jnp.arange(0, M, dtype=jnp.int32)
    gy = jnp.arange(Z - N, Z, dtype=jnp.int32)
    r_xy = jnp.asarray(R[:M, Z - N:], jnp.float32)
    rows_x = jnp.asarray(R[:M], jnp.float32)
    rows_y = jnp.asarray(R[Z - N:], jnp.float32)
    out = ops.pcit_filter(r_xy, rows_x, rows_y, gx, gy, bm=bm, bn=bm, bz=32)
    want = ref.pcit_filter(r_xy, rows_x, rows_y, gx, gy)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("B,Tq,Tk,H,KV,hd,causal",
                         [(2, 128, 128, 4, 2, 64, True),
                          (1, 64, 256, 4, 4, 32, True),
                          (2, 128, 128, 2, 1, 64, False),
                          (1, 256, 256, 8, 2, 128, True)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, Tq, Tk, H, KV, hd, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Tq, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Tk, KV, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Tk, KV, hd)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,T,H,P,N,chunk", [(2, 32, 3, 8, 16, 8),
                                             (1, 64, 2, 16, 8, 16),
                                             (2, 16, 4, 8, 32, 16)])
def test_ssd_chunk_pallas(B, T, H, P, N, chunk):
    x = jnp.asarray(RNG.normal(size=(B, T, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, T, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, T, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, T, N)), jnp.float32)
    got = ops.ssd_chunk(x, dt, A, Bm, Cm, chunk=chunk)
    want = ref.ssd_chunk(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_oracle_matches_model():
    """ref.ssd_chunk (sequential) == models.ssm.ssd_chunked for all chunkings."""
    from repro.models.ssm import ssd_chunked
    B, T, H, P, N = 2, 32, 3, 8, 16
    x = jnp.asarray(RNG.normal(size=(B, T, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, T, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, T, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, T, N)), jnp.float32)
    want = ref.ssd_chunk(x, dt, A, Bm, Cm)
    for chunk in [1, 4, 8, 32]:
        y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k,block,d,Q,topk", [(3, 16, 8, 4, 4),
                                              (4, 12, 24, 5, 8),
                                              (2, 32, 16, 12, 3),
                                              (5, 8, 4, 3, 40)])
@pytest.mark.parametrize("metric", ["dot", "l2"])
def test_query_topk_kernel(k, block, d, Q, topk, metric):
    """Fused query scoring + dedup mask + running top-k kernel vs the
    jnp two-key-sort oracle: exact index match (shared (-score, index)
    order), including masked rows, a fully-masked slot, non-multiple-of-8
    Q (wrapper pads), and topk > candidate count (sentinel fill)."""
    stack = jnp.asarray(RNG.normal(size=(k, block, d)), jnp.float32)
    queries = jnp.asarray(RNG.normal(size=(Q, d)), jnp.float32)
    mask = (RNG.uniform(size=(k, block)) > 0.3).astype(np.float32)
    mask[0] = 0.0                                   # fully-masked slot
    gidx = RNG.permutation(4 * k * block)[:k * block].reshape(k, block)
    got_v, got_i = ops.query_topk(stack, queries, jnp.asarray(mask),
                                  jnp.asarray(gidx, jnp.int32), topk=topk,
                                  metric=metric)
    want_v, want_i = ref.query_topk(stack, queries, mask, gidx, topk=topk,
                                    metric=metric)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("k,block,d,n_pairs,capacity",
                         [(3, 16, 8, 4, 256), (4, 12, 24, 6, 64),
                          (2, 8, 4, 2, 128), (5, 8, 16, 8, 16)])
@pytest.mark.parametrize("metric", ["dot", "l2"])
def test_pairwise_threshold_kernel(k, block, d, n_pairs, capacity, metric):
    """Fused thresholded scoring + sparse compaction kernel vs the jnp
    cumsum oracle: identical compacted (score, i, j) buffers and true
    counts, including inactive (prefiltered) tiles, a self pair with the
    strict-triangle rule, partial row validity, capacity overflow (the
    (5, 8, 16, 8, 16) cell), and non-multiple-of-8 handling through the
    ops wrapper."""
    rng = np.random.default_rng(k * 1000 + block)   # order-independent
    quorum = jnp.asarray(rng.normal(size=(k, block, d)), jnp.float32)
    lo = rng.integers(0, k, size=n_pairs).astype(np.int32)
    hi = rng.integers(0, k, size=n_pairs).astype(np.int32)
    lo[0] = hi[0] = 0                               # self pair
    meta = np.stack([
        np.ones(n_pairs),                           # active
        (lo == hi),                                 # is_self
        rng.permutation(2 * n_pairs)[:n_pairs],     # ga
        rng.permutation(2 * n_pairs)[:n_pairs],     # gb
        np.minimum(block, rng.integers(1, block + 1, n_pairs)),  # nv_lo
        np.minimum(block, rng.integers(1, block + 1, n_pairs)),  # nv_hi
    ], axis=1).astype(np.int32)
    if n_pairs > 1:
        meta[1, 0] = 0                              # a prefiltered tile
    # a mid-quantile threshold (under the metric) so both branches of the
    # compare are hit
    s = np.asarray(quorum[0] @ quorum[-1].T)
    if metric == "l2":
        n0 = np.asarray((quorum[0] ** 2).sum(-1))
        n1 = np.asarray((quorum[-1] ** 2).sum(-1))
        s = 2.0 * s - n1[None, :] - n0[:, None]
    thr = float(np.quantile(s, 0.7))
    got = ops.pairwise_threshold(quorum, lo, hi, jnp.asarray(meta),
                                 threshold=thr, capacity=capacity,
                                 block_rows=block, metric=metric)
    pad = (-block) % 8                              # ref sees padded rows
    qp = jnp.pad(quorum, ((0, 0), (0, pad), (0, 0)))
    capp = -(-capacity // 128) * 128
    want = ref.pairwise_threshold(qp, lo, hi, meta, threshold=thr,
                                  capacity=capp, block_rows=block,
                                  metric=metric)
    np.testing.assert_array_equal(np.asarray(got[1]),
                                  np.asarray(want[1])[:capacity])
    np.testing.assert_array_equal(np.asarray(got[2]),
                                  np.asarray(want[2])[:capacity])
    np.testing.assert_allclose(np.asarray(got[0]),
                               np.asarray(want[0])[:capacity],
                               rtol=1e-5, atol=1e-5)
    assert int(got[3]) == int(want[3])
    if (k, block, d, n_pairs, capacity) == (5, 8, 16, 8, 16):
        assert int(got[3]) > capacity               # overflow cell flags


@pytest.mark.parametrize("k,block,n_pairs", [(2, 8, 2), (3, 12, 5),
                                             (4, 16, 9), (3, 8, 4)])
def test_pairwise_batch_forces(k, block, n_pairs):
    """Fused batched n-body + slot segment-sum kernel vs the jnp oracle,
    including a self pair (wj = 0), masked-out pairs, and non-multiple-of-8
    block sizes (zero-mass padding)."""
    quorum = jnp.asarray(np.concatenate(
        [RNG.normal(size=(k, block, 3)),
         RNG.uniform(0.5, 2, (k, block, 1))], -1), jnp.float32)
    lo = RNG.integers(0, k, size=n_pairs).astype(np.int32)
    hi = RNG.integers(0, k, size=n_pairs).astype(np.int32)
    lo[0] = hi[0] = 0                               # self pair
    wi = RNG.integers(0, 2, size=n_pairs).astype(np.float32)
    wi[0] = 1.0
    wj = wi * (lo != hi)
    got = ops.pairwise_batch_forces(quorum, lo, hi, wi, wj)
    want = ref.pairwise_batch_forces(quorum, lo, hi, wi, wj)
    assert got.shape == (k, block, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("k,block,d,n_pairs,topk,metric",
                         [(3, 8, 16, 4, 4, "dot"),
                          (4, 16, 8, 7, 8, "l2"),
                          (3, 7, 5, 4, 3, "dot"),     # non-multiple-of-8 rows
                          (5, 8, 16, 8, 2, "l2"),
                          (2, 8, 4, 2, 16, "dot")])   # topk > candidates
def test_pairwise_topk_kernel(k, block, d, n_pairs, topk, metric):
    """Fused pair-scoring running-top-k kernel vs the jnp scan oracle:
    identical neighbor indices and scores per slot row, including a self
    pair with the diagonal excluded, an inactive (masked) tile, partial
    row validity, sentinel padding when topk exceeds the candidate
    count, and non-multiple-of-8 handling through the ops wrapper."""
    rng = np.random.default_rng(k * 777 + block)     # order-independent
    quorum = jnp.asarray(rng.normal(size=(k, block, d)), jnp.float32)
    lo = rng.integers(0, k, size=n_pairs).astype(np.int32)
    hi = rng.integers(0, k, size=n_pairs).astype(np.int32)
    lo[0] = hi[0] = 0                                # self pair
    meta = np.stack([
        np.ones(n_pairs),                            # active
        (lo == hi),                                  # is_self
        rng.permutation(2 * n_pairs)[:n_pairs],      # ga (distinct ids)
        rng.permutation(2 * n_pairs)[:n_pairs],      # gb
        np.minimum(block, rng.integers(1, block + 1, n_pairs)),  # nv_lo
        np.minimum(block, rng.integers(1, block + 1, n_pairs)),  # nv_hi
    ], axis=1).astype(np.int32)
    if n_pairs > 1:
        meta[1, 0] = 0                               # a masked-out tile
    got_v, got_i = ops.pairwise_topk(quorum, lo, hi, jnp.asarray(meta),
                                     topk=topk, block_rows=block,
                                     metric=metric)
    pad = (-block) % 8                               # ref sees padded rows
    qp = jnp.pad(quorum, ((0, 0), (0, pad), (0, 0)))
    want_v, want_i = ref.pairwise_topk(qp, lo, hi, meta, topk=topk,
                                       block_rows=block, metric=metric)
    np.testing.assert_array_equal(np.asarray(got_i),
                                  np.asarray(want_i)[:, :block])
    np.testing.assert_allclose(np.asarray(got_v),
                               np.asarray(want_v)[:, :block],
                               rtol=1e-5, atol=1e-5)

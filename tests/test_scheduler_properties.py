"""Hypothesis property sweeps for the schedules (skipped without hypothesis;
deterministic versions run in tests/test_scheduler.py)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.scheduler import build_causal_schedule, build_schedule


@given(st.integers(min_value=1, max_value=96))
@settings(max_examples=40, deadline=None)
def test_full_schedule_exact_coverage(P):
    """Every unordered pair computed exactly once (d = P/2 orbit twice,
    deduplicated by the engine mask)."""
    s = build_schedule(P)
    count = np.zeros((P, P), int)
    for i in range(P):
        for (x, y) in s.global_pairs_of(i):
            a, b = min(x, y), max(x, y)
            count[a, b] += 1
    for a in range(P):
        for b in range(a, P):
            d = (b - a) % P
            dd = min(d, P - d)
            expected = 2 if (P % 2 == 0 and P > 1 and dd == P // 2) else 1
            assert count[a, b] == expected, (P, a, b)


@given(st.integers(min_value=1, max_value=96))
@settings(max_examples=40, deadline=None)
def test_perfect_static_balance(P):
    """Every device owns exactly one pair per difference — identical op
    sequence lengths (straggler-free by construction)."""
    s = build_schedule(P)
    assert s.n_pairs == P // 2 + 1
    for i in range(P):
        assert len(s.global_pairs_of(i)) == s.n_pairs


@given(st.integers(min_value=1, max_value=64))
@settings(max_examples=30, deadline=None)
def test_causal_schedule_coverage(P):
    cs = build_causal_schedule(P)
    cover = np.zeros((P, P), int)
    for i in range(P):
        for sidx in range(cs.n_pairs):
            if cs.valid[i, sidx]:
                kv = (i + int(cs.shifts[cs.pair_slots[sidx, 0]])) % P
                q = (i + int(cs.shifts[cs.pair_slots[sidx, 1]])) % P
                cover[q, kv] += 1
    want = np.tril(np.ones((P, P), int))
    np.testing.assert_array_equal(cover, want)

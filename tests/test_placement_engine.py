"""Differential engine tests over the placement layer.

``quorum_allpairs`` under every registered placement × every execution
mode vs the ``allgather_allpairs`` oracle, at P in {4, 5, 7, 8, 12, 13}
(13 = 3^2+3+1 exercises the projective plane, 12 = 3^2+3 the affine one;
each (placement, P) case runs only where the placement is defined).  The
numeric check runs in fake-device subprocesses via repro.core.selfcheck
(dry-run isolation rule, see tests/test_distributed.py).

The serving tier re-checks the same placements *bit-exactly*: the
(-score, index) total order makes top-k indices integer-equal to the
brute-force oracle (the test_serving.py idiom), through streamed updates
— run here under plane and full placements via repro.serving.selfcheck.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core.placement import registered_placements

SRC = Path(__file__).resolve().parents[1] / "src"

P_SWEEP = (4, 5, 7, 8, 12, 13)

ENGINE_CASES = [
    (P, name)
    for P in P_SWEEP
    for name, cls in sorted(registered_placements().items())
    if cls.supports(P)
]


def run_sub(code: str, devices: int, env_extra: dict | None = None) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    env.update(env_extra or {})
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("P,name", ENGINE_CASES,
                         ids=[f"{n}-P{P}" for P, n in ENGINE_CASES])
def test_engine_placement_matches_oracle(P, name):
    """Every mode (batched/overlap/scan) under the placement == allgather
    == numpy oracle.  The full placement delegates to allgather inside
    the engine — the degenerate-oracle wiring is what's under test."""
    out = run_sub(
        f"from repro.core.selfcheck import main; main({P}, "
        f"placement={name!r})", P)
    assert "selfcheck OK" in out
    assert f"placement={name}(" in out
    assert "batched,overlap,scan" in out


SERVING_CASES = [
    (12, "affine", "batched,overlap,scan,kernel"),
    (13, "projective", "batched,scan,kernel"),
    (5, "full", "batched,overlap,scan,kernel"),
]


@pytest.mark.parametrize("P,name,modes", SERVING_CASES,
                         ids=[f"{n}-P{P}" for P, n, _ in SERVING_CASES])
def test_serving_placement_bit_exact(P, name, modes):
    """Cover-routed top-k under plane/full placements: indices match the
    brute-force oracle exactly ((-score, index) order), scores to float
    tolerance, through streamed replace/append updates."""
    out = run_sub(
        f"from repro.serving.selfcheck import main; "
        f"main({P}, modes=tuple({modes.split(',')!r}), placement={name!r})",
        P)
    assert "serving selfcheck OK" in out
    assert f"placement={name}(" in out


def test_env_placement_reaches_engine():
    """REPRO_PLACEMENT steers implicit placement selection (the CI
    matrix hook) — and `plane` falls back to cyclic where no plane
    exists, so matrix sweeps may include plane-less P."""
    out = run_sub(
        "from repro.core.selfcheck import main; main(7, modes=('batched',))",
        7, env_extra={"REPRO_PLACEMENT": "plane"})
    assert "placement=projective(" in out
    out = run_sub(
        "from repro.core.selfcheck import main; main(5, modes=('batched',))",
        5, env_extra={"REPRO_PLACEMENT": "plane"})
    assert "placement=cyclic(" in out


def test_full_placement_rejects_batch_fn_and_mask():
    """The allgather delegation cannot honor a fused quorum kernel or an
    app-specific pair-validity mask — the engine must reject both rather
    than silently drop them (masked-out pairs would be summed back in)."""
    code = """
import jax.numpy as jnp
from repro.core.allpairs import quorum_allpairs
from repro.core.placement import get_placement
full2 = get_placement("full", 2)
for kwargs, frag in [
    (dict(batch_fn=lambda *a: None), "full-replication"),
    (dict(mask=jnp.ones((2,))), "full-replication"),
]:
    try:
        quorum_allpairs(lambda a, b: (a, b), jnp.zeros((4, 3)),
                        axis_name="q", placement=full2, **kwargs)
    except ValueError as e:
        assert frag in str(e), e
    else:
        raise AssertionError(f"no error for {kwargs} + full placement")

# placement/axis_size and placement/schedule P mismatches fail fast at
# the call site, not deep inside quorum_gather's permutation tables
from repro.core.scheduler import build_schedule
for kwargs in [dict(axis_size=8), dict(schedule=build_schedule(8))]:
    try:
        quorum_allpairs(lambda a, b: (a, b), jnp.zeros((4, 3)),
                        axis_name="q", placement=get_placement("cyclic", 13),
                        **kwargs)
    except ValueError as e:
        assert "P=13" in str(e), e
    else:
        raise AssertionError(f"no error for P mismatch {kwargs}")
print("FULL-GUARD-OK")
"""
    assert "FULL-GUARD-OK" in run_sub(code, 2)

"""Batched serving example: greedy decode on a smoke-config LM with a
sharded KV cache (the decode_32k / long_500k cells lower this exact
serve_step on the production meshes).

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3_14b]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import serve  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_14b")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    seqs = serve(args.arch, smoke=True, batch=args.batch, prompt_len=12,
                 gen_len=24)
    print("sampled token ids (first sequence):", seqs[0].tolist())
    print("OK")


if __name__ == "__main__":
    main()

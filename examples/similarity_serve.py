"""Online similarity serving over a quorum-sharded corpus (the serving
half of the paper's all-pairs similarity workload, cf. Rocket /
all-pairs-similarity production framing in PAPERS.md): build a corpus of
random embeddings, answer nearest-neighbor queries through the
cover-routed top-k engine, stream in new vectors, and watch results
update — all verified against a numpy brute-force oracle.

Run:  PYTHONPATH=src python examples/similarity_serve.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.serving import ServingCorpus  # noqa: E402
from repro.serving.selfcheck import oracle_topk  # noqa: E402


def main():
    P, block, d, topk = 8, 32, 48, 5
    rng = np.random.default_rng(0)
    N = P * block - block                 # leave room for streamed appends
    corpus = rng.normal(size=(N, d)).astype(np.float32)
    queries = rng.normal(size=(4, d)).astype(np.float32)

    mesh = jax.make_mesh((P,), ("q",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sc = ServingCorpus.build(corpus, mesh, block=block)
    plan = sc.plan
    print(f"corpus: {N} vectors in {P} blocks; quorum k={plan.k}; "
          f"queries fan out to {plan.n_cover}/{P} devices "
          f"(cover {list(plan.devices)})")

    full = np.zeros((P * block, d), np.float32)
    full[:N] = corpus
    valid = np.arange(P * block) < N

    def ask(label):
        vals, ids = sc.query(queries, topk=topk, metric="l2")
        want_v, want_i = oracle_topk(full, valid, queries, topk, "l2")
        assert (np.asarray(ids) == want_i).all(), label
        # atol 1e-3: planted near-duplicates give near-zero L2 scores via
        # catastrophic cancellation of ~|q|^2-magnitude terms, so the
        # engine/numpy matmul reduction-order difference (~1e-5 absolute)
        # is relatively large exactly there
        np.testing.assert_allclose(np.asarray(vals), want_v, rtol=1e-4,
                                   atol=1e-3, err_msg=label)
        print(f"{label}: nearest ids per query = "
              f"{[r.tolist() for r in np.asarray(ids)[:, :3]]} (top 3)")

    ask("initial")

    # stream: plant near-duplicates of the queries in a fresh block — they
    # should immediately dominate the neighbor lists
    planted = queries + 0.01 * rng.normal(size=queries.shape).astype(np.float32)
    b = sc.append_block(planted)
    full[b * block:b * block + len(planted)] = planted
    valid[b * block:b * block + len(planted)] = True
    ask(f"after streaming 4 near-duplicates into block {b}")
    _, ids = sc.query(queries, topk=topk, metric="l2")
    assert (np.asarray(ids)[:, 0] == b * block + np.arange(4)).all(), \
        "planted near-duplicates must be the new nearest neighbors"

    # replace that block: the planted vectors vanish again
    fresh = rng.normal(size=(block, d)).astype(np.float32)
    sc.replace_block(b, fresh)
    full[b * block:(b + 1) * block] = fresh
    valid[b * block:(b + 1) * block] = True
    ask(f"after replacing block {b}")
    print("OK")


if __name__ == "__main__":
    main()

"""Thresholded all-pairs similarity join over a quorum-sharded corpus —
the sparse workload of DESIGN.md section 11: report only the vector pairs
whose similarity passes a threshold, with the norm-bound prefilter
skipping whole block pairs and fixed-capacity buffers escalating on
overflow.  Plants a few near-duplicate pairs in a random corpus and
recovers exactly them (verified against the dense brute-force oracle).

Run:  PYTHONPATH=src python examples/similarity_join.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core.sparse import brute_force_join, similarity_join  # noqa: E402


def main():
    P, d, n_dups = 8, 32, 6
    N = 512
    rng = np.random.default_rng(0)
    # unit vectors so cosine similarity == dot product
    corpus = rng.normal(size=(N, d)).astype(np.float32)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
    # plant near-duplicates: row i + tiny noise lands at row N - n_dups + i
    src = rng.choice(N - n_dups, size=n_dups, replace=False)
    for t, s in enumerate(src):
        noisy = corpus[s] + 0.02 * rng.normal(size=d).astype(np.float32)
        corpus[N - n_dups + t] = noisy / np.linalg.norm(noisy)

    mesh = jax.make_mesh((P,), ("q",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    thr = 0.9                      # cosine threshold: near-duplicates only
    res = similarity_join(corpus, mesh, threshold=thr, metric="dot")
    print(f"corpus: {N} unit vectors in {P} blocks; threshold {thr}")
    print(f"join found {res.n_pairs} passing pairs "
          f"(capacity {res.capacity}, {res.escalations} escalations):")
    for i, j, s in zip(res.i, res.j, res.scores):
        print(f"  ({i:3d}, {j:3d})  cos = {s:.4f}")

    wi, wj, wv = brute_force_join(corpus, thr, "dot")
    assert (res.i == wi).all() and (res.j == wj).all(), "oracle mismatch"
    np.testing.assert_allclose(res.scores, wv, rtol=1e-5, atol=1e-5)
    planted = set(zip(src.tolist(),
                      (N - n_dups + np.arange(n_dups)).tolist()))
    found = set(zip(res.i.tolist(), res.j.tolist()))
    assert planted <= {(min(a, b), max(a, b)) for a, b in found}, \
        "every planted near-duplicate pair must pass the join"
    print(f"all {n_dups} planted near-duplicate pairs recovered; "
          "pair set matches the dense brute-force oracle")
    print("OK")


if __name__ == "__main__":
    main()

"""The paper's experiment (section 5), reproduced end-to-end: PCIT gene
co-expression network reconstruction with cyclic quorum distribution —
including the speedup/memory summary of Fig. 2 and a failover demo.

Run:  PYTHONPATH=src python examples/pcit_distributed.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.apps.pcit import pcit_reference, run_quorum_pcit  # noqa: E402
from repro.core.scheduler import build_schedule, reassign  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    N, G = 128, 40
    # synthetic co-expression: 10 latent regulators drive the genes
    Z = rng.normal(size=(10, G))
    X = (rng.normal(size=(N, 10)) @ Z
         + 0.5 * rng.normal(size=(N, G))).astype(np.float32)

    print("single-node O(N^3) PCIT oracle ...")
    t0 = time.perf_counter()
    ref = pcit_reference(X)
    t_ref = time.perf_counter() - t0

    for P in [4, 8]:
        mesh = jax.make_mesh((P,), ("q",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        run_quorum_pcit(X, mesh)  # warm compile
        t0 = time.perf_counter()
        corr, keep = run_quorum_pcit(X, mesh)
        t_q = time.perf_counter() - t0
        s = build_schedule(P)
        assert (keep == ref).all()
        print(f"P={P}: exact match; quorum runtime {t_q*1e3:.1f} ms "
              f"(oracle {t_ref*1e3:.0f} ms); memory/process = "
              f"{s.k}/{P} = {s.k/P:.2%} of all-data")

    # failover: device 3 dies — quorum redundancy reassigns its pairs
    s = build_schedule(8)
    plan = reassign(s, [3])
    print(f"\nfailover(P=8, dead=[3]): {plan.n_recovered} pairs reassigned "
          f"({sum(map(len, plan.extra_pairs.values()))} free, "
          f"{sum(map(len, plan.fetch_pairs.values()))} with one block fetch) "
          "— no recompute of surviving work, no restart")
    print("OK")


if __name__ == "__main__":
    main()

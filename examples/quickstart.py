"""Quickstart: the paper in 60 seconds.

1. Build a cyclic quorum set and verify the all-pairs property (Theorem 1).
2. Run the paper's PCIT application distributed over 8 (virtual) processes
   with O(N/sqrt(P)) memory per process, and check it against the O(N^3)
   single-node oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
(This script re-execs itself with 8 fake XLA host devices.)
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core.quorum import (cyclic_quorums, difference_set,  # noqa: E402
                               verify_all_pairs_property)
from repro.core.scheduler import build_schedule  # noqa: E402
from repro.apps.pcit import (correlation_reference, pcit_reference,  # noqa: E402
                             run_quorum_pcit)


def main():
    P = 8
    A = difference_set(P)
    Q = cyclic_quorums(P)
    print(f"P = {P} processes")
    print(f"relaxed ({P},{len(A)})-difference set A = {A}")
    print(f"quorums (each size k={len(A)}, vs all-data size {P}):")
    for i, S in enumerate(Q):
        print(f"  S_{i} = {S}")
    assert verify_all_pairs_property(Q, P)
    print("all-pairs property verified: every block pair is co-resident "
          "in >= 1 quorum (paper Theorem 1)\n")

    s = build_schedule(P)
    print(f"static schedule: every device computes exactly {s.n_pairs} "
          f"block pairs (perfect balance)\n")

    # --- the paper's application: PCIT gene co-expression -----------------
    rng = np.random.default_rng(0)
    N, G = 64, 24
    Z = rng.normal(size=(6, G))
    X = (rng.normal(size=(N, 6)) @ Z
         + 0.4 * rng.normal(size=(N, G))).astype(np.float32)

    mesh = jax.make_mesh((P,), ("q",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    corr, keep = run_quorum_pcit(X, mesh)
    np.testing.assert_allclose(corr, correlation_reference(X),
                               rtol=1e-4, atol=1e-5)
    assert (keep == pcit_reference(X)).all()
    kept = keep.mean()
    mem_frac = s.k / P
    print(f"quorum PCIT on {N} genes x {G} samples across {P} processes:")
    print(f"  kept edge fraction      : {kept:.3f} (== single-node oracle)")
    print(f"  memory per process      : {mem_frac:.2%} of all-data baseline"
          f" (k/P = {s.k}/{P})")
    print("OK")


if __name__ == "__main__":
    main()

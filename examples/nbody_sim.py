"""N-body simulation with quorum-distributed direct forces (the paper's
motivating family, section 1.2): leapfrog-integrate a small cluster, with
energy drift as the correctness metric.

Run:  PYTHONPATH=src python examples/nbody_sim.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.apps.nbody import (SOFTENING, distributed_forces,  # noqa: E402
                              leapfrog_step)


def energy(bodies, vel):
    p, m = bodies[:, :3], bodies[:, 3]
    ke = 0.5 * (m[:, None] * vel ** 2).sum()
    d = p[None] - p[:, None]
    r = np.sqrt((d ** 2).sum(-1) + SOFTENING)
    pe = -0.5 * (m[:, None] * m[None, :] / r).sum()
    return float(ke + pe)


def main():
    P, N, steps, dt = 8, 256, 100, 1e-3
    rng = np.random.default_rng(0)
    bodies = np.concatenate([rng.normal(size=(N, 3)),
                             rng.uniform(0.5, 1.5, (N, 1))], -1).astype(np.float32)
    vel = 0.1 * rng.normal(size=(N, 3)).astype(np.float32)
    mesh = jax.make_mesh((P,), ("q",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    b, v = jnp.asarray(bodies), jnp.asarray(vel)
    e0 = energy(np.asarray(b), np.asarray(v))
    for step in range(steps):
        f = distributed_forces(b, mesh, strategy="quorum")
        b, v = leapfrog_step(b, v, dt, f)
        if step % 25 == 0:
            e = energy(np.asarray(b), np.asarray(v))
            print(f"step {step:4d}  E = {e:+.4f}  drift = {abs(e-e0)/abs(e0):.2%}")
    e1 = energy(np.asarray(b), np.asarray(v))
    drift = abs(e1 - e0) / abs(e0)
    print(f"energy drift after {steps} steps: {drift:.2%}")
    assert drift < 0.05
    print("OK")


if __name__ == "__main__":
    main()

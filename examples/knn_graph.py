"""All-pairs k-NN graph construction over a quorum-sharded corpus — the
per-row top-k workload of DESIGN.md section 12.3: every corpus row's
exact k nearest neighbors from one distributed pair sweep (the graph
behind graph-based ANN indexes and dedup clustering).  Builds the graph
over a clustered corpus, verifies it against the dense brute-force
oracle, and shows the clusters recovered as mutual-neighbor groups.

Run:  PYTHONPATH=src python examples/knn_graph.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core.knn import brute_force_knn, knn_graph  # noqa: E402


def main():
    P, d, topk = 8, 16, 5
    n_clusters, per_cluster = 12, 24
    N = n_clusters * per_cluster
    rng = np.random.default_rng(0)
    # well-separated cluster centers + tight noise: each row's true
    # nearest neighbors are its cluster siblings.  (Center scale stays
    # moderate: the L2 score 2x·y - |x|^2 - |y|^2 cancels catastrophically
    # for large |x|, and rounding noise would blur genuine neighbor gaps.)
    centers = 3.0 * rng.normal(size=(n_clusters, d)).astype(np.float32)
    corpus = (centers.repeat(per_cluster, axis=0)
              + 0.1 * rng.normal(size=(N, d)).astype(np.float32))
    labels = np.arange(n_clusters).repeat(per_cluster)

    mesh = jax.make_mesh((P,), ("q",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    res = knn_graph(corpus, mesh, topk=topk, metric="l2")
    print(f"corpus: {N} rows, {n_clusters} clusters of {per_cluster}, "
          f"{P} blocks; k = {topk} (metric l2)")

    want = brute_force_knn(corpus, topk, "l2")
    assert (res.indices == want.indices).all(), "oracle mismatch"
    np.testing.assert_allclose(res.scores, want.scores, rtol=1e-5, atol=1e-4)
    print("neighbor lists match the dense brute-force oracle exactly")

    # the graph recovers the clustering: every neighbor shares its row's
    # cluster label
    purity = (labels[res.indices] == labels[:, None]).mean()
    print(f"neighbor purity (same-cluster fraction): {purity:.3f}")
    assert purity == 1.0, "separated clusters must be exactly recovered"

    row = 0
    print(f"row {row} (cluster {labels[row]}) neighbors: "
          f"{res.indices[row].tolist()} "
          f"(all cluster {set(labels[res.indices[row]].tolist())})")
    print("OK")


if __name__ == "__main__":
    main()

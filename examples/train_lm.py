"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
synthetic data with checkpoint/restart (deliverable b).

The model is a scaled-down starcoder2-family decoder (~100M params).  Loss
must fall; the script kills and resumes itself once mid-run to demonstrate
checkpoint/restart fault tolerance.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses
import shutil
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.launch.train import train  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402

# ~100M params: 12 layers x d=640 + 32k vocab (96.6M)
CFG_100M = ModelConfig(
    name="lm100m", family="dense", n_layers=12, d_model=640,
    n_heads=8, n_kv_heads=4, head_dim=80, d_ff=2560, vocab_size=32_000,
    dtype=jax.numpy.float32, remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    print(f"model: {lm.count_params(CFG_100M)/1e6:.1f}M params")
    ckpt = Path("/tmp/repro_train_lm_ckpt")
    shutil.rmtree(ckpt, ignore_errors=True)

    # monkey-wire the 100M config in as a custom arch
    import repro.configs.registry as reg
    import repro.configs as cfgs
    mod = type(sys)("lm100m")
    mod.CONFIG = CFG_100M
    mod.SMOKE = CFG_100M
    sys.modules["repro.configs.lm100m"] = mod
    reg.ARCHS.append("lm100m")

    half = args.steps // 2
    print(f"--- phase 1: steps 0..{half} (then simulated failure) ---")
    losses1 = train("lm100m", smoke=True, steps=half, batch=args.batch,
                    seq=args.seq, ckpt_dir=str(ckpt), ckpt_every=20,
                    lr=1e-3, log_every=20)

    print(f"--- phase 2: restart from checkpoint, continue to {args.steps} ---")
    losses2 = train("lm100m", smoke=True, steps=args.steps, batch=args.batch,
                    seq=args.seq, ckpt_dir=str(ckpt), ckpt_every=50,
                    lr=1e-3, log_every=20, resume=True)

    first, last = losses1[0], losses2[-1]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first * 0.8, "loss did not fall"
    print("OK: loss fell and training resumed from checkpoint")


if __name__ == "__main__":
    main()
